//! Measurement harness (criterion is unavailable offline — DESIGN.md §3).
//!
//! Provides warmup + repeated timing with robust statistics and a
//! throughput helper, used by `rust/benches/*.rs` (harness = false) and
//! the CLI experiment commands.

use std::time::{Duration, Instant};

/// Statistics over a set of per-iteration timings.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p95: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
            p95: samples[((n - 1) as f64 * 0.95) as usize],
            stddev: Duration::from_secs_f64(var.sqrt()),
        }
    }

    /// Mbit/s for `bits` of payload per iteration.
    pub fn throughput_mbps(&self, bits: usize) -> f64 {
        bits as f64 / self.mean.as_secs_f64() / 1e6
    }
}

/// Benchmark runner with warmup and either fixed iterations or a time
/// budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub time_budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            time_budget: Duration::from_secs(3),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            time_budget: Duration::from_millis(800),
        }
    }

    /// Time `f` repeatedly; returns statistics.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            let enough_iters = samples.len() >= self.min_iters;
            let out_of_time = start.elapsed() >= self.time_budget;
            if samples.len() >= self.max_iters || (enough_iters && out_of_time) {
                break;
            }
        }
        Stats::from_samples(samples)
    }
}

/// Pretty milliseconds.
pub fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Fixed-width table printer for bench/experiment reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                out.push_str("| ");
                out.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                out.push(' ');
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.headers);
        for (i, w) in widths.iter().enumerate() {
            out.push_str(if i == 0 { "|" } else { "|" });
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("|\n");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
        ]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
        assert!((s.mean.as_secs_f64() - 0.002).abs() < 1e-9);
    }

    #[test]
    fn throughput() {
        let s = Stats::from_samples(vec![Duration::from_secs(1)]);
        assert!((s.throughput_mbps(1_000_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_min_iters() {
        let b = Bench {
            warmup_iters: 0,
            min_iters: 7,
            max_iters: 7,
            time_budget: Duration::from_millis(1),
        };
        let mut n = 0;
        let s = b.run(|| n += 1);
        assert_eq!(s.iters, 7);
        assert_eq!(n, 7);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bee"]);
        t.row(&["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("| a "));
        assert!(r.contains("| 1 "));
        assert!(r.lines().count() == 3);
    }
}
