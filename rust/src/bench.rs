//! Measurement harness (criterion is unavailable offline — DESIGN.md §3).
//!
//! Provides warmup + repeated timing with robust statistics and a
//! throughput helper, used by `rust/benches/*.rs` (harness = false) and
//! the CLI experiment commands.

use crate::json::Json;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Statistics over a set of per-iteration timings.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p95: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
            p95: samples[((n - 1) as f64 * 0.95) as usize],
            stddev: Duration::from_secs_f64(var.sqrt()),
        }
    }

    /// Mbit/s for `bits` of payload per iteration.
    pub fn throughput_mbps(&self, bits: usize) -> f64 {
        bits as f64 / self.mean.as_secs_f64() / 1e6
    }
}

/// Benchmark runner with warmup and either fixed iterations or a time
/// budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub time_budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            time_budget: Duration::from_secs(3),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            time_budget: Duration::from_millis(800),
        }
    }

    /// Time `f` repeatedly; returns statistics.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            let enough_iters = samples.len() >= self.min_iters;
            let out_of_time = start.elapsed() >= self.time_budget;
            if samples.len() >= self.max_iters || (enough_iters && out_of_time) {
                break;
            }
        }
        Stats::from_samples(samples)
    }
}

/// Pretty milliseconds.
pub fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

// ---------------------------------------------------------------------------
// Worker-scaling ladder (shared by `pbvd scale` and the table3 bench).
// ---------------------------------------------------------------------------

/// One measured rung of the worker-scaling ladder.
#[derive(Clone, Debug)]
pub struct LadderRung {
    /// `"cpu-golden"` (single-threaded reference engine), `"par-cpu"`
    /// (scalar butterfly pool), `"simd-u32"` (8-lane interleaved pool)
    /// or `"simd-u16"` (16-lane narrow-metric pool).
    pub engine: &'static str,
    pub workers: usize,
    /// Wall time of the last stream decode.
    pub wall: Duration,
    pub tp_mbps: f64,
    /// Thread-scaling speedup: T/P relative to the **1-worker pool**
    /// rung, so kernel-swap gain (golden vs pool) is not conflated
    /// with parallel efficiency.
    pub speedup: f64,
    pub utilization: Option<f64>,
    pub imbalance: Option<f64>,
    /// Path-metric width the rung actually ran (u16 falls back to u32
    /// when the spread bound rejects the code/quantizer); 0 = scalar.
    pub metric_bits: u64,
    /// ACS backend the rung's SIMD kernel ran (`"-"` for the scalar
    /// engines, which have no lane backend).
    pub backend: &'static str,
    /// Survivor-ring decision storage per kernel instance (bytes):
    /// `(D + L) * n_states * sel_bytes` for the lane pools,
    /// `(D + L) * ceil(S/64) * 8` for the scalar butterfly pool, 0 for
    /// the poolless golden engine.
    pub survivor_ring_bytes: u64,
    /// Stages the ring retains (`D + L`) vs the stages one forward
    /// pass walks (`D + 2L`); the gap is the windowed-ring saving.
    pub survivor_ring_stages: u64,
    pub survivor_total_stages: u64,
}

/// Measure the worker-scaling ladder over one LLR stream: first the
/// single-threaded golden `CpuEngine` (kernel reference), then a
/// scalar `ParCpuEngine` pool and the lane-interleaved `SimdCpuEngine`
/// at both metric widths (forced u32 and forced u16), each at every
/// requested worker count.  A 1-worker scalar-pool rung is always
/// included and is the speedup baseline — par-N vs par-1 isolates
/// thread scaling, simd-u32-N vs par-N isolates the lane-interleaved
/// kernel gain, simd-u16-N vs simd-u32-N isolates the narrow-metric
/// 16-lane gain, golden vs par-1 isolates the butterfly-kernel swap.
///
/// `base` carries everything but the per-rung engine kind, width and
/// worker count: code preset, geometry, pipeline lanes, the quantizer
/// width the stream was quantized with (sets the pool kernels' BM
/// offset) and the SIMD rungs' ACS backend request (usually auto;
/// `pbvd scale --simd-backend portable` forces one, resolved with the
/// engine's checked fallback).  Every rung's engine is built through
/// [`DecoderConfig::build_engine`](crate::config::DecoderConfig::build_engine)
/// — the same construction path as the CLI and the conformance
/// suites.  Ladder entries of `0` mean "all cores".
pub fn worker_ladder(
    base: &crate::config::DecoderConfig,
    ladder: &[usize],
    llr: &[i32],
    bench: &Bench,
) -> anyhow::Result<Vec<LadderRung>> {
    use crate::config::EngineKind;
    use crate::coordinator::StreamCoordinator;
    use crate::simd::MetricWidth;

    let trellis = base.trellis()?;
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut pools: Vec<usize> = ladder.iter().map(|&w| if w == 0 { auto } else { w }).collect();
    pools.push(1);
    pools.sort_unstable();
    pools.dedup();

    let mut rows: Vec<(&'static str, usize)> = vec![("cpu-golden", 1)];
    rows.extend(pools.iter().map(|&w| ("par-cpu", w)));
    rows.extend(pools.iter().map(|&w| ("simd-u32", w)));
    // only measure the u16 rung when the engine would actually run the
    // u16 kernel — otherwise the forced-W16 engine falls back to u32
    // and the row would mislabel u32 numbers as u16
    if crate::simd::u16_width_eligible(&trellis, base.batch, base.q) {
        rows.extend(pools.iter().map(|&w| ("simd-u16", w)));
    }

    let n_bits = llr.len() / trellis.r;
    // when a performance history is configured (or planning is on),
    // every rung feeds it an observation — the ladder doubles as the
    // adaptive dispatcher's calibration sweep
    let rb = base.resolved();
    let recorder = if rb.plan.enabled_or_default() || rb.plan.history_path_opt().is_some() {
        Some(rb.plan_dispatcher(None))
    } else {
        None
    };
    let mut measured = Vec::new();
    for (engine, workers) in rows {
        let cfg = match engine {
            "cpu-golden" => base.clone().engine(EngineKind::Golden).workers(1),
            "par-cpu" => base.clone().engine(EngineKind::Par).workers(workers),
            "simd-u16" => base
                .clone()
                .engine(EngineKind::Simd)
                .width(MetricWidth::W16)
                .workers(workers),
            _ => base
                .clone()
                .engine(EngineKind::Simd)
                .width(MetricWidth::W32)
                .workers(workers),
        };
        // construct inside the loop so only this rung's pool is alive
        // while it is being measured (idle foreign pools would perturb
        // the scaling numbers)
        let coord = StreamCoordinator::new(cfg.build_engine(&trellis)?, base.lanes);
        let mut last = None;
        let s = bench.run(|| {
            let (_, st) = coord.decode_stream(llr).expect("ladder decode");
            last = Some(st);
        });
        let stats = last.unwrap();
        let tp = n_bits as f64 / s.mean.as_secs_f64() / 1e6;
        if let Some(dsp) = &recorder {
            let arm = match engine {
                "cpu-golden" => crate::plan::Arm::Golden,
                "par-cpu" => crate::plan::Arm::Par,
                "simd-u16" => crate::plan::Arm::SimdW16,
                _ => crate::plan::Arm::SimdW32,
            };
            let shape = crate::plan::BatchShape::new(
                &rb.preset, &trellis, rb.batch, rb.block, rb.depth, workers, rb.q,
            );
            let backend = stats
                .per_worker
                .as_ref()
                .and_then(|p| p.backend_name())
                .unwrap_or("");
            dsp.observe(&shape, arm, backend, tp);
        }
        measured.push((engine, workers, stats, tp));
        // coord (and its engine pool) drops here, joining its workers
    }
    let base_tp = measured
        .iter()
        .find(|(e, w, _, _)| *e == "par-cpu" && *w == 1)
        .map(|&(_, _, _, tp)| tp)
        .unwrap_or(1.0);
    Ok(measured
        .into_iter()
        .map(|(engine, workers, stats, tp)| LadderRung {
            engine,
            workers,
            wall: stats.wall,
            tp_mbps: tp,
            speedup: tp / base_tp,
            utilization: stats.per_worker.as_ref().map(|p| p.utilization(stats.wall)),
            imbalance: stats.per_worker.as_ref().map(|p| p.imbalance()),
            metric_bits: stats.per_worker.as_ref().map_or(0, |p| p.metric_bits),
            backend: stats
                .per_worker
                .as_ref()
                .and_then(|p| p.backend_name())
                .unwrap_or("-"),
            survivor_ring_bytes: stats.per_worker.as_ref().map_or(0, |p| p.survivor_ring_bytes),
            survivor_ring_stages: stats
                .per_worker
                .as_ref()
                .map_or(0, |p| p.survivor_ring_stages),
            survivor_total_stages: stats
                .per_worker
                .as_ref()
                .map_or(0, |p| p.survivor_total_stages),
        })
        .collect())
}

/// Machine-readable bench summary: the `BENCH_<name>.json` artifacts
/// CI uploads per PR so the perf trajectory is trackable over time.
///
/// A report is a flat object of scalars plus named row sections:
///
/// ```json
/// {"bench": "table3", "quick": true,
///  "cpu_par": [{"workers": 8, "tp_mbps": 412.0}, ...]}
/// ```
pub struct BenchReport {
    name: String,
    scalars: Vec<(String, Json)>,
    sections: Vec<(String, Vec<Json>)>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            scalars: Vec::new(),
            sections: Vec::new(),
        }
    }

    /// Set a top-level scalar field.
    pub fn scalar(&mut self, key: &str, val: impl Into<Json>) {
        self.scalars.push((key.to_string(), val.into()));
    }

    /// Append a row object to a named section (created on first use).
    pub fn row(&mut self, section: &str, row: Json) {
        match self.sections.iter_mut().find(|(s, _)| s == section) {
            Some((_, rows)) => rows.push(row),
            None => self.sections.push((section.to_string(), vec![row])),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("bench", Json::from(self.name.clone()));
        for (k, v) in &self.scalars {
            root.set(k, v.clone());
        }
        for (s, rows) in &self.sections {
            root.set(s, Json::Arr(rows.clone()));
        }
        root
    }

    /// Write `BENCH_<name>.json` under `$PBVD_BENCH_DIR` (default: the
    /// current directory); returns the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("PBVD_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        let path = PathBuf::from(dir).join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        Ok(path)
    }
}

/// Fixed-width table printer for bench/experiment reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                out.push_str("| ");
                out.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                out.push(' ');
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.headers);
        for (i, w) in widths.iter().enumerate() {
            out.push_str(if i == 0 { "|" } else { "|" });
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("|\n");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
        ]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
        assert!((s.mean.as_secs_f64() - 0.002).abs() < 1e-9);
    }

    #[test]
    fn throughput() {
        let s = Stats::from_samples(vec![Duration::from_secs(1)]);
        assert!((s.throughput_mbps(1_000_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_min_iters() {
        let b = Bench {
            warmup_iters: 0,
            min_iters: 7,
            max_iters: 7,
            time_budget: Duration::from_millis(1),
        };
        let mut n = 0;
        let s = b.run(|| n += 1);
        assert_eq!(s.iters, 7);
        assert_eq!(n, 7);
    }

    #[test]
    fn bench_report_round_trips_through_json() {
        let mut rep = BenchReport::new("unit");
        rep.scalar("quick", true);
        rep.scalar("bits", 1234usize);
        let mut row = Json::obj();
        row.set("workers", Json::from(4usize));
        row.set("tp_mbps", Json::from(17.5));
        rep.row("cpu_par", row.clone());
        rep.row("cpu_par", row);
        let j = rep.to_json();
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("unit"));
        assert_eq!(j.get("bits").and_then(Json::as_usize), Some(1234));
        assert_eq!(j.get("cpu_par").and_then(Json::as_arr).unwrap().len(), 2);
        // serialized form parses back identically
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.path("cpu_par.1.workers").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bee"]);
        t.row(&["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("| a "));
        assert!(r.contains("| 1 "));
        assert!(r.lines().count() == 3);
    }
}
