//! Online decode-integrity layer: shadow auditing, decode-confidence
//! accounting, and input hardening.
//!
//! Production decoders fail silently: a miscompiled SIMD kernel, a bad
//! rebuild after degradation, or corrupted inputs all produce plausible
//! bits.  This module makes such failures *observable* and *actionable*
//! without touching the hot decode path:
//!
//! * [`ShadowAuditor`] — deterministically samples a configurable
//!   fraction of decoded blocks (seeded and replayable, like a fault
//!   plan) and re-decodes them on a background thread with the golden
//!   scalar [`CpuPbvdDecoder`].  Any divergence in decoded words or
//!   confidence margin becomes a typed [`IntegrityViolation`] carrying
//!   full provenance, counted in
//!   [`IntegrityStats`](crate::metrics::IntegrityStats).
//! * [`AuditedEngine`] — a transparent [`DecodeEngine`] wrapper that
//!   validates inputs, forwards batches unchanged, and feeds the
//!   auditor.  Built by
//!   [`DecoderConfig::build_engine`](crate::config::DecoderConfig::build_engine)
//!   only when the audit section is explicitly configured, so the
//!   default path is untouched.
//! * Input hardening — [`validate_batch_len`] and [`is_all_erasure`]
//!   reject malformed geometry and all-erasure frames (erasure = LLR
//!   0, the [`puncture`](crate::puncture) convention) with typed
//!   [`InputError`]s before they reach an engine.
//!
//! The serve path wires the same auditor into its engine supervisor:
//! a diverging backend is *quarantined* — forced down the
//! simd → par → golden ladder and excluded from rebuilds until the
//! process restarts (see [`serve::supervisor`](crate::serve::supervisor)).

use crate::channel::pack_bits;
use crate::config::AuditConfig;
use crate::coordinator::{BatchTimings, DecodeEngine};
use crate::metrics::IntegrityStats;
use crate::rng::Xoshiro256;
use crate::trellis::Trellis;
use crate::viterbi::CpuPbvdDecoder;
use anyhow::Result;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread;

/// Bounded audit queue: the decode path never blocks on auditing —
/// when the queue is full the sample is shed (and counted).
const AUDIT_QUEUE_CAP: usize = 256;

/// Retained violation records (counters keep exact totals; the record
/// list is a bounded diagnostic ring for STATS and tests).
const MAX_VIOLATION_RECORDS: usize = 64;

fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------------
// Typed input errors.
// ---------------------------------------------------------------------------

/// A malformed decode input, rejected before it reaches an engine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InputError {
    /// The LLR buffer does not match the engine geometry `B*T*R`.
    BadGeometry { got: usize, want: usize },
    /// Every LLR of the frame is an erasure (LLR 0 — the puncturing
    /// convention): the decoder would output pure guesswork with zero
    /// confidence, so the frame is refused instead.
    AllErasure { len: usize },
}

impl fmt::Display for InputError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InputError::BadGeometry { got, want } => {
                write!(f, "bad input geometry: {got} LLRs, engine expects {want}")
            }
            InputError::AllErasure { len } => {
                write!(f, "all-erasure frame refused ({len} LLRs, all zero)")
            }
        }
    }
}

impl std::error::Error for InputError {}

/// True when every LLR is an erasure (the `puncture` convention maps
/// punctured/erased positions to LLR 0).
pub fn is_all_erasure(llr: &[i8]) -> bool {
    llr.iter().all(|&x| x == 0)
}

/// Check an engine input buffer against the `B*T*R` geometry.
pub fn validate_batch_len(got: usize, want: usize) -> Result<(), InputError> {
    if got != want {
        return Err(InputError::BadGeometry { got, want });
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Violations.
// ---------------------------------------------------------------------------

/// What diverged between the audited engine and the golden re-decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DivergenceKind {
    /// The decoded payload words differ — the engine emitted wrong bits.
    Words,
    /// The payload matched but the confidence margin did not — the
    /// metric path diverged even though the decisions survived.
    Margin,
}

/// One detected decode divergence, with full provenance: which engine
/// realization (the name encodes backend, metric width and lane count),
/// which code, which batch and block.
#[derive(Clone, Debug)]
pub struct IntegrityViolation {
    /// Engine realization name (e.g. `simd-cpu:b32w16x16-avx2`).
    pub engine: String,
    /// Code preset the trellis was built from.
    pub preset: String,
    /// Auditor-assigned batch sequence number.
    pub batch_seq: u64,
    /// Block slot within the batch.
    pub block_idx: usize,
    /// Lane the block occupied under a lane-interleaved engine
    /// (`block_idx mod LANES`; informative only for `simd-cpu`).
    pub lane: usize,
    pub kind: DivergenceKind,
}

impl fmt::Display for IntegrityViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "integrity violation ({:?}) on {} [{}]: batch {} block {} lane {}",
            self.kind, self.engine, self.preset, self.batch_seq, self.block_idx, self.lane
        )
    }
}

// ---------------------------------------------------------------------------
// The shadow auditor.
// ---------------------------------------------------------------------------

struct AuditJob {
    llr: Arc<[i8]>,
    /// This block's `[T, R]` window within `llr`.
    offset: usize,
    per_pb: usize,
    expected_words: Vec<u32>,
    /// `None` when the engine surfaced no margins (PJRT backends):
    /// only the words are checked.
    expected_margin: Option<u32>,
    engine: String,
    batch_seq: u64,
    block_idx: usize,
}

/// State shared between callers and the audit thread.
struct AuditShared {
    stats: Arc<IntegrityStats>,
    preset: String,
    quarantine_policy: bool,
    processed: AtomicU64,
    violations: Mutex<Vec<IntegrityViolation>>,
    /// Latched by the audit thread, drained by the engine supervisor
    /// (no Arc cycle: the auditor never references the supervisor).
    pending_quarantine: Mutex<Option<IntegrityViolation>>,
}

/// Deterministic sampling shadow auditor (see the [module
/// docs](crate::audit)).
///
/// Dropping the auditor closes the queue and joins the audit thread;
/// in-flight samples are processed first.
pub struct ShadowAuditor {
    shared: Arc<AuditShared>,
    tx: Mutex<Option<SyncSender<AuditJob>>>,
    handle: Mutex<Option<thread::JoinHandle<()>>>,
    sample_ppm: u32,
    seed: u64,
    low_margin: u32,
    r: usize,
    per_pb: usize,
    batch_seq: AtomicU64,
    enqueued: AtomicU64,
}

impl ShadowAuditor {
    /// Spawn the audit thread for one engine geometry.  The golden
    /// re-decoder is built once, on the thread.
    pub fn new(trellis: &Trellis, block: usize, depth: usize, cfg: &AuditConfig) -> ShadowAuditor {
        Self::with_stats(trellis, block, depth, cfg, Arc::new(IntegrityStats::new()))
    }

    /// [`new`](ShadowAuditor::new) with an externally shared
    /// [`IntegrityStats`] (the serve path aggregates scheduler-side
    /// counters into the same object).
    pub fn with_stats(
        trellis: &Trellis,
        block: usize,
        depth: usize,
        cfg: &AuditConfig,
        stats: Arc<IntegrityStats>,
    ) -> ShadowAuditor {
        let shared = Arc::new(AuditShared {
            stats,
            preset: trellis.name.clone(),
            quarantine_policy: cfg.quarantine_or_default(),
            processed: AtomicU64::new(0),
            violations: Mutex::new(Vec::new()),
            pending_quarantine: Mutex::new(None),
        });
        let (tx, rx) = sync_channel::<AuditJob>(AUDIT_QUEUE_CAP);
        let t = trellis.clone();
        let sh = Arc::clone(&shared);
        let handle = thread::Builder::new()
            .name("pbvd-audit".into())
            .spawn(move || {
                let golden = CpuPbvdDecoder::new(&t, block, depth);
                let mut llr32 = vec![0i32; golden.total() * t.r];
                while let Ok(job) = rx.recv() {
                    let src = &job.llr[job.offset..job.offset + job.per_pb];
                    for (dst, &s) in llr32.iter_mut().zip(src) {
                        *dst = s as i32;
                    }
                    let (bits, margin) = golden.decode_block_with_margin(&llr32);
                    sh.stats.record_audited();
                    let kind = if pack_bits(&bits) != job.expected_words {
                        Some(DivergenceKind::Words)
                    } else if job.expected_margin.is_some_and(|m| m != margin) {
                        Some(DivergenceKind::Margin)
                    } else {
                        None
                    };
                    if let Some(kind) = kind {
                        match kind {
                            DivergenceKind::Words => sh.stats.record_violation(),
                            DivergenceKind::Margin => sh.stats.record_margin_mismatch(),
                        }
                        let v = IntegrityViolation {
                            engine: job.engine,
                            preset: sh.preset.clone(),
                            batch_seq: job.batch_seq,
                            block_idx: job.block_idx,
                            lane: job.block_idx % crate::simd::LANES,
                            kind,
                        };
                        let mut log = relock(&sh.violations);
                        if log.len() < MAX_VIOLATION_RECORDS {
                            log.push(v.clone());
                        }
                        drop(log);
                        if sh.quarantine_policy {
                            relock(&sh.pending_quarantine).get_or_insert(v);
                        }
                    }
                    sh.processed.fetch_add(1, Ordering::Release);
                }
            })
            .expect("spawn audit thread");
        ShadowAuditor {
            shared,
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            sample_ppm: cfg.sample_ppm_or_default(),
            seed: cfg.seed_or_default(),
            low_margin: cfg.low_margin_or_default(),
            r: trellis.r,
            per_pb: (block + 2 * depth) * trellis.r,
            batch_seq: AtomicU64::new(0),
            enqueued: AtomicU64::new(0),
        }
    }

    /// The shared integrity counters.
    pub fn stats(&self) -> &Arc<IntegrityStats> {
        &self.shared.stats
    }

    /// Effective low-confidence margin floor (`0` = disabled).
    pub fn low_margin(&self) -> u32 {
        self.low_margin
    }

    /// Deterministic per-(batch, block) sampling decision — a pure
    /// function of (seed, seq, idx), so the same traffic replays the
    /// same audit schedule.
    pub fn should_audit(&self, seq: u64, idx: usize) -> bool {
        if self.sample_ppm >= 1_000_000 {
            return true;
        }
        if self.sample_ppm == 0 {
            return false;
        }
        let mix = seq
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((idx as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        Xoshiro256::seeded(self.seed ^ mix).next_below(1_000_000) < self.sample_ppm as u64
    }

    /// Observe one decoded batch: count low-confidence blocks and
    /// enqueue the sampled ones for golden re-decode.  `llr` is the
    /// batch the engine ACTUALLY decoded correct results from — under
    /// fault injection the caller must pass the clean buffer, not a
    /// corrupted dispatch copy.  Never blocks: full-queue samples are
    /// shed and counted.
    pub fn observe_batch(
        &self,
        engine: &str,
        llr: &Arc<[i8]>,
        words: &[u32],
        margins: &[u32],
        used_blocks: usize,
    ) {
        let seq = self.batch_seq.fetch_add(1, Ordering::Relaxed);
        if self.low_margin > 0 {
            let low = margins
                .iter()
                .take(used_blocks)
                .filter(|&&m| m < self.low_margin)
                .count();
            if low > 0 {
                self.shared.stats.record_low_confidence(low as u64);
            }
        }
        let words_per_pb = words.len() / self.expected_blocks(llr.len());
        let tx = relock(&self.tx);
        let Some(tx) = tx.as_ref() else { return };
        for idx in 0..used_blocks {
            if !self.should_audit(seq, idx) {
                continue;
            }
            let offset = idx * self.per_pb;
            // zero-padded (all-erasure) slots carry no information —
            // skip them rather than audit guesswork
            if is_all_erasure(&llr[offset..offset + self.per_pb]) {
                continue;
            }
            let job = AuditJob {
                llr: Arc::clone(llr),
                offset,
                per_pb: self.per_pb,
                expected_words: words[idx * words_per_pb..(idx + 1) * words_per_pb].to_vec(),
                expected_margin: margins.get(idx).copied(),
                engine: engine.to_string(),
                batch_seq: seq,
                block_idx: idx,
            };
            match tx.try_send(job) {
                Ok(()) => {
                    self.enqueued.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                    self.shared.stats.record_shed_audit();
                }
            }
        }
    }

    fn expected_blocks(&self, llr_len: usize) -> usize {
        (llr_len / self.per_pb).max(1)
    }

    /// Drain the pending quarantine request, if the audit thread
    /// latched one.  Polled by the engine supervisor before dispatch.
    pub fn take_quarantine(&self) -> Option<IntegrityViolation> {
        relock(&self.shared.pending_quarantine).take()
    }

    /// The retained violation records (bounded; counters are exact).
    pub fn violations(&self) -> Vec<IntegrityViolation> {
        relock(&self.shared.violations).clone()
    }

    /// Block until every enqueued sample has been re-decoded (test
    /// hook; bounded at ~5 s so a wedged thread fails loudly instead
    /// of hanging the suite).
    pub fn flush(&self) {
        let target = self.enqueued.load(Ordering::Relaxed);
        for _ in 0..5000 {
            if self.shared.processed.load(Ordering::Acquire) >= target {
                return;
            }
            thread::sleep(std::time::Duration::from_millis(1));
        }
        panic!("audit thread failed to drain ({target} enqueued)");
    }
}

impl Drop for ShadowAuditor {
    fn drop(&mut self) {
        relock(&self.tx).take(); // close the queue
        if let Some(h) = relock(&self.handle).take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// The audited engine wrapper.
// ---------------------------------------------------------------------------

/// Transparent [`DecodeEngine`] wrapper: validates inputs, delegates
/// the decode unchanged, then feeds the auditor.  `name()` and every
/// geometry accessor pass through, so the wrapper is invisible to
/// coordinators, supervisors and stats.
pub struct AuditedEngine {
    inner: Arc<dyn DecodeEngine>,
    auditor: Arc<ShadowAuditor>,
}

impl AuditedEngine {
    pub fn new(inner: Arc<dyn DecodeEngine>, auditor: Arc<ShadowAuditor>) -> AuditedEngine {
        AuditedEngine { inner, auditor }
    }

    /// The wrapped auditor (stats, flush, violations).
    pub fn auditor(&self) -> &Arc<ShadowAuditor> {
        &self.auditor
    }

    fn expected_len(&self) -> usize {
        self.inner.batch() * self.inner.total() * self.inner.r()
    }
}

impl DecodeEngine for AuditedEngine {
    fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
        validate_batch_len(llr_i8.len(), self.expected_len())?;
        if is_all_erasure(llr_i8) {
            self.auditor.stats().record_rejected_input();
            return Err(InputError::AllErasure { len: llr_i8.len() }.into());
        }
        let (words, t) = self.inner.decode_batch(llr_i8)?;
        let shared: Arc<[i8]> = llr_i8.into();
        self.auditor
            .observe_batch(&self.inner.name(), &shared, &words, &t.margins, self.inner.batch());
        Ok((words, t))
    }

    fn decode_batch_shared(&self, llr_i8: &Arc<[i8]>) -> Result<(Vec<u32>, BatchTimings)> {
        validate_batch_len(llr_i8.len(), self.expected_len())?;
        if is_all_erasure(llr_i8) {
            self.auditor.stats().record_rejected_input();
            return Err(InputError::AllErasure { len: llr_i8.len() }.into());
        }
        let (words, t) = self.inner.decode_batch_shared(llr_i8)?;
        self.auditor
            .observe_batch(&self.inner.name(), llr_i8, &words, &t.margins, self.inner.batch());
        Ok((words, t))
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }
    fn block(&self) -> usize {
        self.inner.block()
    }
    fn depth(&self) -> usize {
        self.inner.depth()
    }
    fn r(&self) -> usize {
        self.inner.r()
    }
    fn name(&self) -> String {
        self.inner.name()
    }
    fn worker_snapshot(&self) -> Option<crate::metrics::WorkerSnapshot> {
        self.inner.worker_snapshot()
    }
    fn install_fault_plan(&self, plan: Option<Arc<crate::serve::faults::FaultPlan>>) {
        self.inner.install_fault_plan(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CpuEngine;
    use crate::encoder::ConvEncoder;

    fn audit_all() -> AuditConfig {
        AuditConfig {
            sample_ppm: Some(1_000_000),
            seed: Some(7),
            quarantine: Some(true),
            low_margin: None,
        }
    }

    fn clean_batch(t: &Trellis, batch: usize, block: usize, depth: usize, seed: u64) -> Arc<[i8]> {
        let total = block + 2 * depth;
        let mut rng = Xoshiro256::seeded(seed);
        let mut buf = vec![0i8; batch * total * t.r];
        for b in 0..batch {
            let bits: Vec<u8> = (0..total).map(|_| rng.next_bit()).collect();
            let mut e = ConvEncoder::new(t);
            let coded = e.encode(&bits);
            for (dst, &c) in buf[b * total * t.r..].iter_mut().zip(&coded) {
                *dst = if c == 0 { 8 } else { -8 };
            }
        }
        buf.into()
    }

    #[test]
    fn sampling_is_deterministic_and_roughly_calibrated() {
        let t = Trellis::preset("k3").unwrap();
        let cfg = AuditConfig {
            sample_ppm: Some(250_000), // 25%
            seed: Some(42),
            ..AuditConfig::default()
        };
        let a = ShadowAuditor::new(&t, 32, 15, &cfg);
        let b = ShadowAuditor::new(&t, 32, 15, &cfg);
        let mut hits = 0usize;
        for seq in 0..200u64 {
            for idx in 0..8usize {
                assert_eq!(a.should_audit(seq, idx), b.should_audit(seq, idx));
                hits += a.should_audit(seq, idx) as usize;
            }
        }
        // 1600 draws at 25%: expect ~400, accept a generous band
        assert!((240..=560).contains(&hits), "hits = {hits}");
        // a different seed yields a different schedule
        let c = ShadowAuditor::new(
            &t,
            32,
            15,
            &AuditConfig { seed: Some(43), ..cfg },
        );
        let diff = (0..200u64)
            .flat_map(|s| (0..8usize).map(move |i| (s, i)))
            .filter(|&(s, i)| a.should_audit(s, i) != c.should_audit(s, i))
            .count();
        assert!(diff > 0, "distinct seeds must produce distinct schedules");
    }

    #[test]
    fn clean_engine_produces_zero_violations() {
        let t = Trellis::preset("k3").unwrap();
        let inner = Arc::new(CpuEngine::new(&t, 4, 32, 15));
        let auditor = Arc::new(ShadowAuditor::new(&t, 32, 15, &audit_all()));
        let eng = AuditedEngine::new(inner, Arc::clone(&auditor));
        let llr = clean_batch(&t, 4, 32, 15, 9);
        for _ in 0..3 {
            eng.decode_batch_shared(&llr).unwrap();
        }
        auditor.flush();
        assert_eq!(auditor.stats().audited(), 12);
        assert_eq!(auditor.stats().violations(), 0);
        assert_eq!(auditor.stats().margin_mismatches(), 0);
        assert!(auditor.take_quarantine().is_none());
    }

    #[test]
    fn corrupted_words_are_detected_with_provenance() {
        struct LyingEngine(CpuEngine);
        impl DecodeEngine for LyingEngine {
            fn decode_batch(&self, llr_i8: &[i8]) -> Result<(Vec<u32>, BatchTimings)> {
                let (mut words, t) = self.0.decode_batch(llr_i8)?;
                words[0] ^= 1; // flip one decoded bit of block 0
                Ok((words, t))
            }
            fn batch(&self) -> usize {
                self.0.batch()
            }
            fn block(&self) -> usize {
                self.0.block()
            }
            fn depth(&self) -> usize {
                self.0.depth()
            }
            fn r(&self) -> usize {
                self.0.r()
            }
            fn name(&self) -> String {
                "lying-cpu:b4".into()
            }
        }
        let t = Trellis::preset("k3").unwrap();
        let auditor = Arc::new(ShadowAuditor::new(&t, 32, 15, &audit_all()));
        let eng = AuditedEngine::new(
            Arc::new(LyingEngine(CpuEngine::new(&t, 4, 32, 15))),
            Arc::clone(&auditor),
        );
        let llr = clean_batch(&t, 4, 32, 15, 10);
        eng.decode_batch_shared(&llr).unwrap();
        auditor.flush();
        assert_eq!(auditor.stats().violations(), 1);
        let v = &auditor.violations()[0];
        assert_eq!(v.engine, "lying-cpu:b4");
        assert_eq!(v.preset, "k3");
        assert_eq!(v.block_idx, 0);
        assert_eq!(v.kind, DivergenceKind::Words);
        // the quarantine request is latched exactly once
        assert!(auditor.take_quarantine().is_some());
        assert!(auditor.take_quarantine().is_none());
    }

    #[test]
    fn input_hardening_rejects_bad_geometry_and_erasure() {
        let t = Trellis::preset("k3").unwrap();
        let auditor = Arc::new(ShadowAuditor::new(&t, 32, 15, &audit_all()));
        let eng = AuditedEngine::new(
            Arc::new(CpuEngine::new(&t, 2, 32, 15)),
            Arc::clone(&auditor),
        );
        let short: Arc<[i8]> = vec![1i8; 7].into();
        let err = eng.decode_batch_shared(&short).unwrap_err();
        assert!(err.downcast_ref::<InputError>().is_some(), "{err}");
        let erased: Arc<[i8]> = vec![0i8; 2 * (32 + 30) * t.r].into();
        let err = eng.decode_batch_shared(&erased).unwrap_err();
        assert_eq!(
            err.downcast_ref::<InputError>(),
            Some(&InputError::AllErasure { len: erased.len() })
        );
        assert_eq!(auditor.stats().rejected_inputs(), 1);
    }

    #[test]
    fn low_margin_floor_counts_weak_blocks() {
        let t = Trellis::preset("k3").unwrap();
        let cfg = AuditConfig {
            low_margin: Some(u32::MAX), // every real block is "weak"
            ..audit_all()
        };
        let auditor = Arc::new(ShadowAuditor::new(&t, 32, 15, &cfg));
        let eng = AuditedEngine::new(
            Arc::new(CpuEngine::new(&t, 4, 32, 15)),
            Arc::clone(&auditor),
        );
        let llr = clean_batch(&t, 4, 32, 15, 11);
        eng.decode_batch_shared(&llr).unwrap();
        auditor.flush();
        assert_eq!(auditor.stats().low_confidence(), 4);
    }
}
