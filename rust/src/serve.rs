//! Decode-as-a-service: the `pbvd serve` daemon.
//!
//! The paper's Gb/s headline numbers only materialize when every lane
//! group runs full — throughput is a function of batch occupancy.  A
//! one-shot CLI can only fill a 16-lane u16 group from a single
//! caller; this module turns the decoder into a long-running daemon
//! that coalesces frames *across* concurrent client streams into full
//! lane groups before dispatching them to one shared engine built
//! through the unified [`DecoderConfig`](crate::config::DecoderConfig)
//! factory.
//!
//! Layers (std `TcpListener` + the `pool.rs` threading idioms — no
//! async runtime, no new dependencies):
//!
//! * [`protocol`] — the length-prefixed wire format with a versioned
//!   fixed header, and the typed [`ServeError`] surface: every
//!   failure a client can provoke (bad magic, wrong version, oversize
//!   payload, wrong frame length, bad HELLO bytes, …) is a value, not
//!   a panic, so one malicious client cannot abort the process.
//! * [`scheduler`] — admission of per-stream frame queues (bounded =
//!   backpressure), cross-stream coalescing with a flush deadline so
//!   a trickle stream cannot stall a full group, one dispatch at a
//!   time to the shared engine, and exact per-stream QoS attribution
//!   built on `BatchTimings::per_worker`.
//! * [`session`] — [`PbvdServer`]: accept loop with admission
//!   control, per-client reader/writer thread pairs, heartbeats on
//!   idle, and a stall detector that evicts wedged clients without
//!   disturbing the other streams.
//! * [`client`] — [`ServeClient`]: the blocking loopback client the
//!   integration tests (and examples) drive the daemon with.
//!
//! ```no_run
//! use pbvd::config::DecoderConfig;
//! use pbvd::serve::{PbvdServer, ServeClient};
//!
//! let cfg = DecoderConfig::new("ccsds_k7").serve_bind("127.0.0.1:0");
//! let server = PbvdServer::bind(&cfg, None).unwrap();
//! let mut client = ServeClient::connect(server.local_addr()).unwrap();
//! let llr = vec![0i32; 2 * 10_000];
//! let bits = client.decode_stream(&llr, 8).unwrap();
//! assert_eq!(bits.len(), 10_000);
//! ```

pub mod client;
pub mod protocol;
pub mod scheduler;
pub mod session;

pub use client::{ServeClient, ServerInfo};
pub use protocol::{Message, ServeError, Verb, MAX_PAYLOAD, PROTO_VERSION};
pub use scheduler::Scheduler;
pub use session::PbvdServer;
