//! Decode-as-a-service: the `pbvd serve` daemon.
//!
//! The paper's Gb/s headline numbers only materialize when every lane
//! group runs full — throughput is a function of batch occupancy.  A
//! one-shot CLI can only fill a 16-lane u16 group from a single
//! caller; this module turns the decoder into a long-running daemon
//! that coalesces frames *across* concurrent client streams into full
//! lane groups before dispatching them to one shared engine built
//! through the unified [`DecoderConfig`](crate::config::DecoderConfig)
//! factory.
//!
//! Layers (std `TcpListener` + the `pool.rs` threading idioms — no
//! async runtime, no new dependencies):
//!
//! * [`protocol`] — the length-prefixed wire format with a versioned
//!   fixed header (v2 adds RESUME), and the typed [`ServeError`]
//!   surface: every failure a client can provoke (bad magic, wrong
//!   version, oversize payload, wrong frame length, bad HELLO bytes,
//!   an expired resume token, …) is a value, not a panic, so one
//!   malicious client cannot abort the process.
//! * [`scheduler`] — admission of per-stream frame queues (bounded =
//!   backpressure), cross-stream coalescing with a flush deadline so
//!   a trickle stream cannot stall a full group, one dispatch at a
//!   time to the shared engine, exact per-stream QoS attribution
//!   built on `BatchTimings::per_worker`, overload shedding with a
//!   typed `retry_after` hint, and the replay buffers behind
//!   reconnect/resume.
//! * [`supervisor`] — [`EngineSupervisor`]: self-healing wrapper
//!   around the shared engine; a failed group dispatch is retried
//!   once, then the engine is rebuilt one rung down the
//!   `simd → par → golden` ladder at the same geometry, so a worker
//!   panic degrades throughput instead of killing every stream.  The
//!   supervisor also hosts the decode-integrity hooks
//!   ([`crate::audit`]): when a shadow-audited block diverges from
//!   the golden re-decode, the blamed backend is *quarantined* —
//!   forced down the same ladder and excluded from rebuilds — and the
//!   daemon rejects all-erasure SUBMIT frames with a typed
//!   `erased_frame` refusal before they reach the engine.
//! * [`session`] — [`PbvdServer`]: accept loop with admission
//!   control, per-client reader/writer thread pairs, heartbeats on
//!   idle, a stall detector that evicts wedged clients without
//!   disturbing the other streams, and the resume registry that parks
//!   lost streams for a grace window.
//! * [`faults`] — [`FaultPlan`]: the seeded, deterministic
//!   fault-injection layer (`PBVD_FAULTS` / `--faults`) whose hooks
//!   sit at the read, write, dispatch, and worker seams; zero-cost
//!   when no plan is installed.  The chaos conformance suite drives
//!   the daemon through it.
//! * [`client`] — [`ServeClient`]: the blocking, self-healing
//!   loopback client the integration and chaos tests drive the daemon
//!   with — socket deadlines ([`ServeError::Timeout`]), capped-backoff
//!   reconnect, RESUME replay, and per-frame `retry_after` honoring.
//!
//! ```no_run
//! use pbvd::config::DecoderConfig;
//! use pbvd::serve::{PbvdServer, ServeClient};
//!
//! let cfg = DecoderConfig::new("ccsds_k7").serve_bind("127.0.0.1:0");
//! let server = PbvdServer::bind(&cfg, None).unwrap();
//! let mut client = ServeClient::connect(server.local_addr()).unwrap();
//! let llr = vec![0i32; 2 * 10_000];
//! let bits = client.decode_stream(&llr, 8).unwrap();
//! assert_eq!(bits.len(), 10_000);
//! ```

pub mod client;
pub mod faults;
pub mod protocol;
pub mod scheduler;
pub mod session;
pub mod supervisor;

pub use client::{ClientOptions, ServeClient, ServerInfo};
pub use faults::FaultPlan;
pub use protocol::{Message, ServeError, Verb, MAX_PAYLOAD, PROTO_VERSION};
pub use scheduler::Scheduler;
pub use session::PbvdServer;
pub use supervisor::EngineSupervisor;
