//! CPU reference decoders — bit-exact golden models for the kernels and
//! the comparison baselines of Table III / ablation A1.
//!
//! * [`CpuPbvdDecoder`] — the parallel block-based decoder of Sec. III
//!   on the CPU: group-based forward ACS producing the *same* packed
//!   survivor-path words as the Pallas K1 kernel (Fig. 3 layout), and
//!   the Algorithm-1 K2 traceback over them.  Integer path metrics make
//!   every decision exact; with integer (quantized) LLRs the decisions
//!   coincide with the f32 kernel bit-for-bit (sums stay < 2^24).
//! * [`BlockViterbiDecoder`] — the classic block VA (known start state,
//!   argmin-final-state traceback) used to quantify PBVD truncation loss.
//! * `forward_statebased` — the 2^K-BM baseline (ablation A1): same
//!   decisions, more branch-metric work.

use crate::trellis::Trellis;

/// Survivor paths + final path metrics of one parallel block.
///
/// Survivor words live in a depth-windowed ring of `ring_stages =
/// D + L` rows rather than the full `T = D + 2L` buffer: stage `s`
/// occupies row `s % ring_stages`, so the forward pass overwrites the
/// first `L` stages — which Algorithm-1 traceback never reads (it
/// walks `L..T`) — with the last `L`.  The retained window `L..T`
/// spans exactly `D + L` consecutive stages and therefore maps
/// bijectively onto the ring rows, keeping the traceback bit-identical
/// to a full-length buffer while survivor memory becomes independent
/// of the leading warm-up overlap.
#[derive(Clone, Debug)]
pub struct ForwardResult {
    /// `[ring_stages][n_sp_words]` packed survivor words, row-major;
    /// stage `s` is at row `s % ring_stages`.
    pub sp: Vec<u32>,
    /// Final path metrics `[N]` (normalized: min = 0 each stage).
    pub pm: Vec<i64>,
    pub n_sp_words: usize,
    /// Forward stages processed (`T = D + 2L` for a full block).
    pub total_stages: usize,
    /// Ring capacity in stages (`D + L`, `< total_stages`).
    pub ring_stages: usize,
}

impl ForwardResult {
    /// Decode-confidence margin of this block: the runner-up final
    /// path metric.  Metrics are min-normalized every stage, so the
    /// winning state's metric is exactly 0 and the second-smallest
    /// metric *is* the winner-vs-runner-up gap.  A margin of 0 means
    /// two end states tie — the decode is genuinely ambiguous (the
    /// all-erasure frame is the degenerate case: every metric is 0).
    ///
    /// Saturates to `u32::MAX` for unquantized inputs; for every
    /// quantized preset the spread bound `2*K*R*2^q` keeps it exact,
    /// which is what makes the margin bit-identical across the
    /// scalar, butterfly and lane-interleaved kernels.
    pub fn margin(&self) -> u32 {
        second_min_margin(self.pm.iter().map(|&m| m.min(u32::MAX as i64) as u32))
    }
}

/// Runner-up metric of one block's min-normalized final path metrics
/// (the shared margin definition for every kernel: winner is 0, so
/// the second-smallest value is the confidence gap).
pub fn second_min_margin(pm: impl IntoIterator<Item = u32>) -> u32 {
    let (mut best, mut second) = (u32::MAX, u32::MAX);
    for m in pm {
        if m < best {
            second = best;
            best = m;
        } else if m < second {
            second = m;
        }
    }
    second
}

/// The PBVD on the CPU.  `block` = D decoded bits per PB, `depth` = L
/// (M = L, Sec. III-A), so each PB spans `T = D + 2L` stages.
#[derive(Clone, Debug)]
pub struct CpuPbvdDecoder {
    trellis: Trellis,
    pub block: usize,
    pub depth: usize,
}

impl CpuPbvdDecoder {
    pub fn new(trellis: &Trellis, block: usize, depth: usize) -> Self {
        assert!(block > 0 && depth > 0);
        Self {
            trellis: trellis.clone(),
            block,
            depth,
        }
    }

    /// Stages per parallel block.
    pub fn total(&self) -> usize {
        self.block + 2 * self.depth
    }

    /// Survivor-ring capacity in stages: `D + L`, the traceback window
    /// `L..T` folded onto reusable rows (see [`ForwardResult`]).
    pub fn ring_stages(&self) -> usize {
        self.block + self.depth
    }

    /// Bytes of survivor storage one forward pass retains with the
    /// depth-windowed ring (vs `total() * n_sp_words * 4` full-length).
    pub fn survivor_ring_bytes(&self) -> usize {
        self.ring_stages() * self.trellis.n_sp_words * std::mem::size_of::<u32>()
    }

    pub fn trellis(&self) -> &Trellis {
        &self.trellis
    }

    /// Branch-metric table for one stage: `BM[c] = Σ_r llr_r (2c_r − 1)`.
    #[inline]
    fn bm_table(&self, llr_stage: &[i32], bm: &mut [i64]) {
        let r = self.trellis.r;
        for (c, slot) in bm.iter_mut().enumerate() {
            let mut acc = 0i64;
            for (ri, &y) in llr_stage.iter().enumerate().take(r) {
                let bit = (c >> (r - 1 - ri)) & 1;
                acc += y as i64 * (2 * bit as i64 - 1);
            }
            *slot = acc;
        }
    }

    /// Group-based forward ACS over `llr` (stage-major `[T][R]` flat).
    /// Produces the kernel-identical packed survivor words.
    pub fn forward(&self, llr: &[i32]) -> ForwardResult {
        self.forward_impl(llr, false)
    }

    /// State-based forward (ablation A1): identical decisions, but the
    /// BM for every transition is recomputed per butterfly (2^K-scale
    /// work) instead of read from the 2^R-entry group table.
    pub fn forward_statebased(&self, llr: &[i32]) -> ForwardResult {
        self.forward_impl(llr, true)
    }

    fn forward_impl(&self, llr: &[i32], statebased: bool) -> ForwardResult {
        let t = &self.trellis;
        let r = t.r;
        let tt = llr.len() / r;
        assert_eq!(llr.len(), tt * r);
        let n = t.n_states;
        let half = n / 2;
        let w = t.n_sp_words;

        let ring = self.ring_stages().min(tt.max(1));
        let mut pm = vec![0i64; n];
        let mut new_pm = vec![0i64; n];
        let mut sp = vec![0u32; ring * w];
        let mut bm = vec![0i64; 1 << r];

        for s in 0..tt {
            let llr_s = &llr[s * r..(s + 1) * r];
            if statebased {
                // recompute correlations per transition below
            } else {
                self.bm_table(llr_s, &mut bm);
            }
            // ring slot: stages older than the traceback horizon are
            // overwritten (OR-packed rows must be cleared on reuse)
            let slot = s % ring;
            let sp_row = &mut sp[slot * w..(slot + 1) * w];
            sp_row.fill(0);
            let mut min_pm = i64::MAX;
            for j in 0..half {
                let pe = pm[2 * j];
                let po = pm[2 * j + 1];
                let (bma, bmg, bmb, bmt) = if statebased {
                    (
                        corr(llr_s, t.cw_top0[j], r),
                        corr(llr_s, t.cw_top1[j], r),
                        corr(llr_s, t.cw_bot0[j], r),
                        corr(llr_s, t.cw_bot1[j], r),
                    )
                } else {
                    (
                        bm[t.cw_top0[j] as usize],
                        bm[t.cw_top1[j] as usize],
                        bm[t.cw_bot0[j] as usize],
                        bm[t.cw_bot1[j] as usize],
                    )
                };
                // target j (input 0): predecessors 2j (alpha), 2j+1 (gamma)
                let a = pe + bma;
                let b = po + bmg;
                let sel_top = b < a;
                let m_top = if sel_top { b } else { a };
                new_pm[j] = m_top;
                // target j + N/2 (input 1): beta / theta
                let a2 = pe + bmb;
                let b2 = po + bmt;
                let sel_bot = b2 < a2;
                let m_bot = if sel_bot { b2 } else { a2 };
                new_pm[j + half] = m_bot;
                min_pm = min_pm.min(m_top).min(m_bot);
                if sel_top {
                    sp_row[t.sp_word[j] as usize] |= 1 << t.sp_bit[j];
                }
                if sel_bot {
                    sp_row[t.sp_word[j + half] as usize] |=
                        1 << t.sp_bit[j + half];
                }
            }
            // normalize (same rescale as the kernel)
            for x in new_pm.iter_mut() {
                *x -= min_pm;
            }
            std::mem::swap(&mut pm, &mut new_pm);
        }
        ForwardResult {
            sp,
            pm,
            n_sp_words: w,
            total_stages: tt,
            ring_stages: ring,
        }
    }

    /// Algorithm-1 K2 traceback over packed survivor words.
    /// Emits the D mid-block bits; `start_state` is arbitrary (Sec.
    /// III-A — the merge phase absorbs it).
    pub fn traceback(&self, fwd: &ForwardResult, start_state: usize) -> Vec<u8> {
        let t = &self.trellis;
        let (d, l) = (self.block, self.depth);
        let tt = fwd.total_stages;
        assert_eq!(tt, d + 2 * l, "forward length != D + 2L");
        let ring = fwd.ring_stages;
        let v = t.v;
        let mask = (1usize << (v - 1)) - 1;
        let mut state = start_state;
        let mut bits = vec![0u8; d];
        for s in (l..tt).rev() {
            if s <= d + l - 1 {
                bits[s - l] = ((state >> (v - 1)) & 1) as u8;
            }
            let slot = s % ring;
            let row = &fwd.sp[slot * fwd.n_sp_words..(slot + 1) * fwd.n_sp_words];
            let word = row[t.sp_word[state] as usize];
            let bit = ((word >> t.sp_bit[state]) & 1) as usize;
            state = 2 * (state & mask) + bit;
        }
        bits
    }

    /// Decode one parallel block: llr `[T*R]` -> D bits.
    pub fn decode_block(&self, llr: &[i32]) -> Vec<u8> {
        let fwd = self.forward(llr);
        self.traceback(&fwd, 0)
    }

    /// Decode one parallel block and report its confidence margin
    /// ([`ForwardResult::margin`]) — the golden reference every other
    /// kernel's margin is pinned bit-identical to.
    pub fn decode_block_with_margin(&self, llr: &[i32]) -> (Vec<u8>, u32) {
        let fwd = self.forward(llr);
        let margin = fwd.margin();
        (self.traceback(&fwd, 0), margin)
    }

    /// Decode a full LLR stream (stage-major, `n_bits * R` values) into
    /// `n_bits` decoded bits, framing it into overlapping PBs exactly as
    /// the coordinator does (zero-LLR padding at the boundaries).
    pub fn decode_stream(&self, llr: &[i32]) -> Vec<u8> {
        let r = self.trellis.r;
        let n_bits = llr.len() / r;
        assert_eq!(llr.len(), n_bits * r);
        let (d, l) = (self.block, self.depth);
        let tt = self.total();
        let n_blocks = n_bits.div_ceil(d);
        let mut out = vec![0u8; n_bits];
        let mut pb = vec![0i32; tt * r];
        for i in 0..n_blocks {
            let begin = i as isize * d as isize - l as isize;
            // gather [begin, begin + T) stages, zero-padded outside stream
            for s in 0..tt {
                let src = begin + s as isize;
                let dst = &mut pb[s * r..(s + 1) * r];
                if src < 0 || src as usize >= n_bits {
                    dst.fill(0);
                } else {
                    let src = src as usize;
                    dst.copy_from_slice(&llr[src * r..(src + 1) * r]);
                }
            }
            let bits = self.decode_block(&pb);
            let take = d.min(n_bits - i * d);
            out[i * d..i * d + take].copy_from_slice(&bits[..take]);
        }
        out
    }
}

/// Correlation BM of one codeword against a stage's LLRs (state-based
/// baseline's per-transition computation).
#[inline]
fn corr(llr_s: &[i32], cw: u32, r: usize) -> i64 {
    let mut acc = 0i64;
    for (ri, &y) in llr_s.iter().enumerate().take(r) {
        let bit = (cw >> (r - 1 - ri)) & 1;
        acc += y as i64 * (2 * bit as i64 - 1);
    }
    acc
}

/// Classic block Viterbi (known zero start state, argmin traceback,
/// decodes every stage).  The truncation-free upper bound for Fig. 4.
#[derive(Clone, Debug)]
pub struct BlockViterbiDecoder {
    trellis: Trellis,
}

impl BlockViterbiDecoder {
    pub fn new(trellis: &Trellis) -> Self {
        Self {
            trellis: trellis.clone(),
        }
    }

    /// Decode an entire coded block (stage-major LLRs), assuming the
    /// encoder started at state 0.  Returns one bit per stage.
    pub fn decode(&self, llr: &[i32]) -> Vec<u8> {
        let t = &self.trellis;
        let r = t.r;
        let tt = llr.len() / r;
        let n = t.n_states;
        let half = n / 2;
        const INF: i64 = i64::MAX / 4;

        let mut pm = vec![INF; n];
        pm[0] = 0;
        let mut new_pm = vec![0i64; n];
        let mut sel = vec![0u8; tt * n];
        let mut bm = vec![0i64; 1 << r];
        for s in 0..tt {
            let llr_s = &llr[s * r..(s + 1) * r];
            for (c, slot) in bm.iter_mut().enumerate() {
                *slot = corr(llr_s, c as u32, r);
            }
            let sel_row = &mut sel[s * n..(s + 1) * n];
            for j in 0..half {
                let pe = pm[2 * j];
                let po = pm[2 * j + 1];
                let a = pe.saturating_add(bm[t.cw_top0[j] as usize]);
                let b = po.saturating_add(bm[t.cw_top1[j] as usize]);
                sel_row[j] = (b < a) as u8;
                new_pm[j] = a.min(b);
                let a2 = pe.saturating_add(bm[t.cw_bot0[j] as usize]);
                let b2 = po.saturating_add(bm[t.cw_bot1[j] as usize]);
                sel_row[j + half] = (b2 < a2) as u8;
                new_pm[j + half] = a2.min(b2);
            }
            std::mem::swap(&mut pm, &mut new_pm);
        }
        let mut state = pm
            .iter()
            .enumerate()
            .min_by_key(|&(_, &m)| m)
            .map(|(i, _)| i)
            .unwrap();
        let v = t.v;
        let mask = (1usize << (v - 1)) - 1;
        let mut bits = vec![0u8; tt];
        for s in (0..tt).rev() {
            bits[s] = ((state >> (v - 1)) & 1) as u8;
            let b = sel[s * n + state] as usize;
            state = 2 * (state & mask) + b;
        }
        bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::ConvEncoder;
    use crate::rng::Xoshiro256;
    use crate::trellis::Trellis;

    fn clean_llrs(t: &Trellis, bits: &[u8], amp: i32) -> Vec<i32> {
        let mut e = ConvEncoder::new(t);
        e.encode(bits)
            .iter()
            .map(|&b| if b == 0 { amp } else { -amp })
            .collect()
    }

    #[test]
    fn pbvd_recovers_clean_block() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let mut rng = Xoshiro256::seeded(1);
        let bits: Vec<u8> = (0..dec.total()).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let out = dec.decode_block(&llr);
        assert_eq!(out, bits[42..42 + 64]);
    }

    #[test]
    fn pbvd_start_state_invariance() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let mut rng = Xoshiro256::seeded(2);
        let bits: Vec<u8> = (0..dec.total()).map(|_| rng.next_bit()).collect();
        let mut llr = clean_llrs(&t, &bits, 8);
        // mild noise
        for x in llr.iter_mut() {
            *x += (rng.next_below(5) as i32) - 2;
        }
        let fwd = dec.forward(&llr);
        let base = dec.traceback(&fwd, 0);
        for s0 in [1usize, 17, 42, 63] {
            assert_eq!(dec.traceback(&fwd, s0), base, "start {s0}");
        }
    }

    #[test]
    fn survivor_ring_is_depth_windowed() {
        // ring capacity D + L, never full-length T — and repeated
        // tracebacks against the ring stay valid after one forward
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let mut rng = Xoshiro256::seeded(31);
        let bits: Vec<u8> = (0..dec.total()).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let fwd = dec.forward(&llr);
        assert_eq!(fwd.ring_stages, dec.ring_stages());
        assert_eq!(fwd.ring_stages, 64 + 42);
        assert_eq!(fwd.total_stages, dec.total());
        assert!(fwd.ring_stages < fwd.total_stages);
        assert_eq!(fwd.sp.len(), fwd.ring_stages * fwd.n_sp_words);
        assert_eq!(
            dec.survivor_ring_bytes(),
            fwd.sp.len() * std::mem::size_of::<u32>()
        );
        let first = dec.traceback(&fwd, 0);
        assert_eq!(first, bits[42..42 + 64]);
        assert_eq!(dec.traceback(&fwd, 0), first, "traceback must not consume");
    }

    #[test]
    fn ring_handles_depth_ge_block() {
        // depth >= block: the ring wraps more than once per forward
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 8, 42);
        assert!(dec.depth >= dec.block);
        let mut rng = Xoshiro256::seeded(32);
        let n = 100usize;
        let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        assert_eq!(dec.decode_stream(&llr), bits);
    }

    #[test]
    fn statebased_forward_identical() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let mut rng = Xoshiro256::seeded(3);
        let llr: Vec<i32> = (0..dec.total() * t.r)
            .map(|_| (rng.next_below(255) as i32) - 127)
            .collect();
        let a = dec.forward(&llr);
        let b = dec.forward_statebased(&llr);
        assert_eq!(a.sp, b.sp);
        assert_eq!(a.pm, b.pm);
    }

    #[test]
    fn stream_decode_roundtrip() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let mut rng = Xoshiro256::seeded(4);
        let n = 1000usize; // not a multiple of D -> exercises padding
        let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let out = dec.decode_stream(&llr);
        assert_eq!(out.len(), n);
        assert_eq!(out, bits);
    }

    #[test]
    fn stream_decode_all_presets() {
        for (name, _, _) in crate::trellis::PRESETS {
            let t = Trellis::preset(name).unwrap();
            let l = (5 * t.k as usize).next_multiple_of(1);
            let dec = CpuPbvdDecoder::new(&t, 48, l);
            let mut rng = Xoshiro256::seeded(5);
            let n = 300usize;
            let bits: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
            let llr = clean_llrs(&t, &bits, 8);
            assert_eq!(dec.decode_stream(&llr), bits, "{name}");
        }
    }

    #[test]
    fn block_va_decodes_with_tail() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let mut rng = Xoshiro256::seeded(6);
        let bits: Vec<u8> = (0..200).map(|_| rng.next_bit()).collect();
        let mut e = ConvEncoder::new(&t);
        let mut coded = e.encode(&bits);
        coded.extend(e.terminate());
        let llr: Vec<i32> = coded
            .iter()
            .map(|&b| if b == 0 { 8 } else { -8 })
            .collect();
        let dec = BlockViterbiDecoder::new(&t);
        let out = dec.decode(&llr);
        assert_eq!(&out[..200], &bits[..]);
    }

    #[test]
    fn pbvd_matches_block_va_mid_block() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let bva = BlockViterbiDecoder::new(&t);
        let mut rng = Xoshiro256::seeded(7);
        let tt = dec.total();
        let bits: Vec<u8> = (0..tt).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let pbvd = dec.decode_block(&llr);
        let va = bva.decode(&llr);
        assert_eq!(pbvd[..], va[42..42 + 64]);
    }

    #[test]
    fn margin_is_runner_up_metric() {
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let mut rng = Xoshiro256::seeded(21);
        let bits: Vec<u8> = (0..dec.total()).map(|_| rng.next_bit()).collect();
        let llr = clean_llrs(&t, &bits, 8);
        let fwd = dec.forward(&llr);
        // winner is 0 after per-stage normalization; margin = 2nd min
        let mut sorted = fwd.pm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted[0], 0);
        assert_eq!(fwd.margin() as i64, sorted[1]);
        assert!(fwd.margin() > 0, "clean decode must be confident");
        let (out, margin) = dec.decode_block_with_margin(&llr);
        assert_eq!(out, bits[42..42 + 64]);
        assert_eq!(margin, fwd.margin());
        // all-erasure frame: every metric 0 -> genuinely ambiguous
        let zeros = vec![0i32; dec.total() * t.r];
        assert_eq!(dec.forward(&zeros).margin(), 0);
        // degenerate iterator shapes stay total
        assert_eq!(second_min_margin(std::iter::empty::<u32>()), u32::MAX);
        assert_eq!(second_min_margin([0u32]), u32::MAX);
        assert_eq!(second_min_margin([5u32, 3]), 5);
    }

    #[test]
    fn corrects_errors_at_high_snr() {
        // flip a few coded bits; VA must correct them
        let t = Trellis::preset("ccsds_k7").unwrap();
        let dec = CpuPbvdDecoder::new(&t, 64, 42);
        let mut rng = Xoshiro256::seeded(8);
        let bits: Vec<u8> = (0..dec.total()).map(|_| rng.next_bit()).collect();
        let mut llr = clean_llrs(&t, &bits, 8);
        // flip 6 well-separated coded bits (well under d_free/2 per span)
        for i in 0..6 {
            let pos = 40 * i + 11;
            llr[pos] = -llr[pos];
        }
        let out = dec.decode_block(&llr);
        assert_eq!(out, bits[42..42 + 64]);
    }
}
