//! Lightweight property-testing driver (proptest is unavailable offline
//! — DESIGN.md §3).  Runs a closure over seeded random cases; on
//! failure, reports the seed so the case can be replayed exactly.

use crate::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 64,
            base_seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` for `cfg.cases` seeded cases.  The closure receives a
/// fresh deterministic RNG per case and returns `Err(msg)` on property
/// violation; panics with the failing seed for replay.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Xoshiro256) -> Result<(), String>,
{
    // honor PBVD_PROP_SEED for replay of a single case
    if let Ok(seed) = std::env::var("PBVD_PROP_SEED") {
        let seed: u64 = seed.parse().expect("PBVD_PROP_SEED must be u64");
        let mut rng = Xoshiro256::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed for replay seed {seed}: {msg}");
        }
        return;
    }
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (replay: \
                 PBVD_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Random bit vector of length `n`.
pub fn random_bits(rng: &mut Xoshiro256, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.next_bit()).collect()
}

/// Encode a random payload, push through AWGN at `ebn0_db`, quantize to
/// 8 bits.  Returns (payload bits, quantized LLR stream).  Shared by
/// benches and examples.
pub fn gen_noisy_stream(
    trellis: &crate::trellis::Trellis,
    n_bits: usize,
    ebn0_db: f64,
    seed: u64,
) -> (Vec<u8>, Vec<i32>) {
    let mut rng = Xoshiro256::seeded(seed);
    let bits = random_bits(&mut rng, n_bits);
    let mut enc = crate::encoder::ConvEncoder::new(trellis);
    let coded = enc.encode(&bits);
    let mut ch = crate::channel::AwgnChannel::new(
        ebn0_db, 1.0 / trellis.r as f64, &mut rng,
    );
    let soft = ch.transmit(&coded);
    (bits, crate::channel::Quantizer::new(8).quantize(&soft))
}

/// Random i32 LLRs in [-mag, mag].
pub fn random_llrs(rng: &mut Xoshiro256, n: usize, mag: i32) -> Vec<i32> {
    (0..n)
        .map(|_| (rng.next_below((2 * mag + 1) as u64) as i32) - mag)
        .collect()
}

/// Mirror of `SimdCpuEngine`'s dispatch plan — full lane-groups, then
/// (u16 mode) one peeled 8-PB u32 sub-group off an 8..16-PB tail, then
/// a scalar remainder job.  The job-count oracle shared by the SIMD
/// test suites so the plan is asserted from exactly one place.
pub fn expected_simd_jobs(batch: usize, lanes: usize) -> u64 {
    let mut jobs = batch / lanes;
    let mut tail = batch % lanes;
    if lanes == crate::simd::LANES_U16 && tail >= crate::simd::LANES {
        jobs += 1;
        tail -= crate::simd::LANES;
    }
    (jobs + usize::from(tail > 0)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", PropConfig { cases: 10, base_seed: 1 }, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "PBVD_PROP_SEED")]
    fn failing_property_reports_seed() {
        check("fails", PropConfig { cases: 3, base_seed: 2 }, |_rng| {
            Err("nope".into())
        });
    }

    #[test]
    fn generators_deterministic() {
        let mut a = Xoshiro256::seeded(5);
        let mut b = Xoshiro256::seeded(5);
        assert_eq!(random_bits(&mut a, 100), random_bits(&mut b, 100));
        assert_eq!(random_llrs(&mut a, 50, 127), random_llrs(&mut b, 50, 127));
        let llrs = random_llrs(&mut a, 1000, 31);
        assert!(llrs.iter().all(|&x| (-31..=31).contains(&x)));
    }
}
