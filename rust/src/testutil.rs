//! Lightweight property-testing driver (proptest is unavailable offline
//! — DESIGN.md §3) plus the backend-parametrized conformance harness
//! ([`oracle_matrix`] / [`oracle_matrix_stream`]) shared by the
//! bit-identity suites (`rust/tests/simd_engine.rs`,
//! `rust/tests/par_engine.rs`, `rust/tests/overflow_guard.rs`,
//! `rust/tests/backend_conformance.rs`).  The property driver runs a
//! closure over seeded random cases; on failure, it reports the seed
//! so the case can be replayed exactly.

use crate::config::DecoderConfig;
use crate::coordinator::{CpuEngine, DecodeEngine, StreamCoordinator};
use crate::par::ParCpuEngine;
use crate::rng::Xoshiro256;
use crate::simd::{AcsBackend, BackendChoice, MetricWidth, SimdCpuEngine, SimdTuning};
use crate::trellis::Trellis;
use std::sync::Arc;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 64,
            base_seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` for `cfg.cases` seeded cases.  The closure receives a
/// fresh deterministic RNG per case and returns `Err(msg)` on property
/// violation; panics with the failing seed for replay.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Xoshiro256) -> Result<(), String>,
{
    // honor PBVD_PROP_SEED for replay of a single case
    if let Ok(seed) = std::env::var("PBVD_PROP_SEED") {
        let seed: u64 = seed.parse().expect("PBVD_PROP_SEED must be u64");
        let mut rng = Xoshiro256::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed for replay seed {seed}: {msg}");
        }
        return;
    }
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (replay: \
                 PBVD_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Random bit vector of length `n`.
pub fn random_bits(rng: &mut Xoshiro256, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.next_bit()).collect()
}

/// Encode a random payload, push through AWGN at `ebn0_db`, quantize to
/// 8 bits.  Returns (payload bits, quantized LLR stream).  Shared by
/// benches and examples.
pub fn gen_noisy_stream(
    trellis: &crate::trellis::Trellis,
    n_bits: usize,
    ebn0_db: f64,
    seed: u64,
) -> (Vec<u8>, Vec<i32>) {
    let mut rng = Xoshiro256::seeded(seed);
    let bits = random_bits(&mut rng, n_bits);
    let mut enc = crate::encoder::ConvEncoder::new(trellis);
    let coded = enc.encode(&bits);
    let mut ch = crate::channel::AwgnChannel::new(
        ebn0_db, 1.0 / trellis.r as f64, &mut rng,
    );
    let soft = ch.transmit(&coded);
    (bits, crate::channel::Quantizer::new(8).quantize(&soft))
}

/// Random i32 LLRs in [-mag, mag].
pub fn random_llrs(rng: &mut Xoshiro256, n: usize, mag: i32) -> Vec<i32> {
    (0..n)
        .map(|_| (rng.next_below((2 * mag + 1) as u64) as i32) - mag)
        .collect()
}

/// Mirror of `SimdCpuEngine`'s dispatch plan — full lane-groups, then
/// (u16 mode) one peeled 8-PB u32 sub-group off an 8..16-PB tail, then
/// a scalar remainder job.  The job-count oracle shared by the SIMD
/// test suites so the plan is asserted from exactly one place.
pub fn expected_simd_jobs(batch: usize, lanes: usize) -> u64 {
    let mut jobs = batch / lanes;
    let mut tail = batch % lanes;
    if lanes == crate::simd::LANES_U16 && tail >= crate::simd::LANES {
        jobs += 1;
        tail -= crate::simd::LANES;
    }
    (jobs + usize::from(tail > 0)) as u64
}

// ---------------------------------------------------------------------------
// The backend-parametrized conformance harness.
// ---------------------------------------------------------------------------

/// Which sharded CPU engine a conformance cell builds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Scalar butterfly pool (`ParCpuEngine`) — no width/backend axes
    /// (those cells collapse to one run per worker count).
    Par,
    /// Lane-interleaved SIMD pool (`SimdCpuEngine`) — the full
    /// width × backend matrix applies.
    Simd,
}

/// `engines` axis containing only the SIMD pool.
pub const SIMD_ONLY: [EngineKind; 1] = [EngineKind::Simd];
/// `engines` axis covering both sharded pools.
pub const BOTH_ENGINES: [EngineKind; 2] = [EngineKind::Par, EngineKind::Simd];
/// `widths` axis covering both metric widths.
pub const BOTH_WIDTHS: [MetricWidth; 2] = [MetricWidth::W32, MetricWidth::W16];

/// One conformance matrix: every
/// `engines × widths × backends × batches × workers` cell decodes the
/// same input and must be bit-identical to the golden `CpuEngine`.
/// The `backends` slice should normally be [`AcsBackend::available`]
/// so each suite automatically covers Scalar/Portable/AVX2/NEON
/// wherever they exist on the build host.
pub struct OracleMatrix<'a> {
    pub trellis: &'a Trellis,
    pub block: usize,
    pub depth: usize,
    pub q: u32,
    pub engines: &'a [EngineKind],
    pub widths: &'a [MetricWidth],
    pub backends: &'a [AcsBackend],
    pub batches: &'a [usize],
    pub workers: &'a [usize],
}

/// The flattened cell list of a matrix.  `Par` cells carry no
/// width/backend (one run per worker count); `Simd` cells span the
/// full width × backend product.
fn cells(m: &OracleMatrix) -> Vec<(EngineKind, MetricWidth, Option<AcsBackend>, usize)> {
    let mut v = Vec::new();
    for &kind in m.engines {
        match kind {
            EngineKind::Par => {
                for &w in m.workers {
                    v.push((kind, MetricWidth::W32, None, w));
                }
            }
            EngineKind::Simd => {
                for &width in m.widths {
                    for &b in m.backends {
                        for &w in m.workers {
                            v.push((kind, width, Some(b), w));
                        }
                    }
                }
            }
        }
    }
    v
}

fn cell_label(
    m: &OracleMatrix,
    label: &str,
    batch: usize,
    kind: EngineKind,
    width: MetricWidth,
    backend: Option<AcsBackend>,
    workers: usize,
) -> String {
    format!(
        "{label}: {} B={batch} D={} L={} q={} {kind:?} {width:?} backend={} workers={workers}",
        m.trellis.name,
        m.block,
        m.depth,
        m.q,
        backend.map_or("-", |b| b.name()),
    )
}

/// The [`DecoderConfig`] of one matrix cell — every harness engine is
/// built through [`DecoderConfig::build_engine`], the same single
/// construction path the CLI, coordinator and benches use, so the
/// conformance matrices prove the factory itself.
fn cell_config(
    m: &OracleMatrix,
    batch: usize,
    kind: EngineKind,
    width: MetricWidth,
    backend: Option<AcsBackend>,
    workers: usize,
) -> DecoderConfig {
    let mut cfg = DecoderConfig::new(&m.trellis.name)
        .batch(batch)
        .block(m.block)
        .depth(m.depth)
        .workers(workers)
        .width(width)
        .q(m.q)
        .engine(match kind {
            EngineKind::Par => crate::config::EngineKind::Par,
            EngineKind::Simd => crate::config::EngineKind::Simd,
        });
    if let Some(b) = backend {
        cfg = cfg.backend(BackendChoice::Forced(b));
    }
    cfg
}

/// Batch-level conformance driver: for every batch size, `make_llr`
/// produces one shared i8 batch (`batch * (D + 2L) * R` values), the
/// golden `CpuEngine` decodes it once, and every matrix cell must
/// reproduce that output bit-for-bit — with exact worker attribution,
/// the SIMD dispatch plan's job count ([`expected_simd_jobs`] at the
/// *resolved* lane width), and the resolved metric width + backend
/// recorded consistently in the engine name and pool snapshot.
///
/// Every cell engine is built through
/// [`DecoderConfig::build_engine`] (the unified construction path),
/// and additionally cross-checked against a *directly constructed*
/// engine (`ParCpuEngine::with_quantizer` /
/// `SimdCpuEngine::with_config`) — the factory and the low-level
/// constructors must produce identically named, bit-identical
/// engines for every cell of the matrix.
pub fn oracle_matrix(
    m: &OracleMatrix,
    label: &str,
    mut make_llr: impl FnMut(usize) -> Vec<i8>,
) -> Result<(), String> {
    let t = m.trellis;
    let per_pb = (m.block + 2 * m.depth) * t.r;
    for &batch in m.batches {
        let llr = make_llr(batch);
        if llr.len() != batch * per_pb {
            return Err(format!(
                "{label}: make_llr produced {} LLRs for batch {batch}, want {}",
                llr.len(),
                batch * per_pb
            ));
        }
        let (want, golden_t) = CpuEngine::new(t, batch, m.block, m.depth)
            .decode_batch(&llr)
            .map_err(|e| format!("{label}: golden decode failed: {e}"))?;
        if golden_t.margins.len() != batch {
            return Err(format!(
                "{label}: golden engine reported {} margins for batch {batch}",
                golden_t.margins.len()
            ));
        }
        for (kind, width, backend, workers) in cells(m) {
            let ctx = cell_label(m, label, batch, kind, width, backend, workers);
            let cfg = cell_config(m, batch, kind, width, backend, workers);
            let eng = cfg
                .build_engine(t)
                .map_err(|e| format!("{ctx}: build_engine failed: {e}"))?;
            let (got, timings) = eng
                .decode_batch(&llr)
                .map_err(|e| format!("{ctx}: decode failed: {e}"))?;
            if got != want {
                return Err(format!("{ctx}: decode diverged from golden CpuEngine"));
            }
            // decode confidence: the per-PB path-metric margins are part
            // of the conformance contract — bit-identical across every
            // engine × width × backend × worker cell
            if timings.margins != golden_t.margins {
                return Err(format!(
                    "{ctx}: path-metric margins diverged from golden ({:?} != {:?})",
                    timings.margins, golden_t.margins
                ));
            }
            let pw = timings
                .per_worker
                .ok_or_else(|| format!("{ctx}: no per-call attribution"))?;
            if pw.total_blocks() != batch as u64 {
                return Err(format!(
                    "{ctx}: attributed {} blocks, want {batch}",
                    pw.total_blocks()
                ));
            }
            // survivor-memory invariant: every pool engine must report
            // a depth-windowed decision ring (capacity D + L stages),
            // never the full-length T = D + 2L buffer
            if pw.survivor_ring_stages != (m.block + m.depth) as u64
                || pw.survivor_total_stages != (m.block + 2 * m.depth) as u64
            {
                return Err(format!(
                    "{ctx}: survivor ring {} of {} stages, want {} of {}",
                    pw.survivor_ring_stages,
                    pw.survivor_total_stages,
                    m.block + m.depth,
                    m.block + 2 * m.depth
                ));
            }
            if pw.survivor_ring_bytes == 0 || pw.survivor_ring_stages >= pw.survivor_total_stages {
                return Err(format!(
                    "{ctx}: survivor storage not depth-windowed ({} bytes, {} of {} stages)",
                    pw.survivor_ring_bytes, pw.survivor_ring_stages, pw.survivor_total_stages
                ));
            }
            match kind {
                EngineKind::Par => {
                    // factory vs direct construction: same name, same bits
                    let direct =
                        ParCpuEngine::with_quantizer(t, batch, m.block, m.depth, workers, m.q);
                    if direct.name() != eng.name() {
                        return Err(format!(
                            "{ctx}: config-built engine {:?} != directly-constructed {:?}",
                            eng.name(),
                            direct.name()
                        ));
                    }
                    let (dgot, _) = direct
                        .decode_batch(&llr)
                        .map_err(|e| format!("{ctx}: direct decode failed: {e}"))?;
                    if dgot != want {
                        return Err(format!("{ctx}: direct engine diverged from golden"));
                    }
                }
                EngineKind::Simd => {
                    let b = backend.expect("simd cells carry a backend");
                    // factory vs direct construction: identical
                    // resolution (the name encodes the resolved lane
                    // width, worker count and backend) and identical
                    // decisions
                    let direct = SimdCpuEngine::with_config(
                        t,
                        batch,
                        m.block,
                        m.depth,
                        workers,
                        SimdTuning {
                            width,
                            q: m.q,
                            backend: BackendChoice::Forced(b),
                        },
                    );
                    if direct.name() != eng.name() {
                        return Err(format!(
                            "{ctx}: config-built engine {:?} != directly-constructed {:?}",
                            eng.name(),
                            direct.name()
                        ));
                    }
                    if direct.backend() != b {
                        return Err(format!(
                            "{ctx}: engine resolved backend {:?} instead of the available \
                             forced one",
                            direct.backend()
                        ));
                    }
                    let (dgot, _) = direct
                        .decode_batch(&llr)
                        .map_err(|e| format!("{ctx}: direct decode failed: {e}"))?;
                    if dgot != want {
                        return Err(format!("{ctx}: direct engine diverged from golden"));
                    }
                    let want_jobs = expected_simd_jobs(batch, direct.lane_width());
                    if pw.total_jobs() != want_jobs {
                        return Err(format!(
                            "{ctx}: {} lane-group jobs, want {want_jobs}",
                            pw.total_jobs()
                        ));
                    }
                    if pw.metric_bits != direct.metric_bits() {
                        return Err(format!(
                            "{ctx}: snapshot reports u{}, engine runs u{}",
                            pw.metric_bits,
                            direct.metric_bits()
                        ));
                    }
                    if pw.backend != b.code() {
                        return Err(format!(
                            "{ctx}: snapshot reports backend code {}, want {}",
                            pw.backend,
                            b.code()
                        ));
                    }
                    if !eng.name().ends_with(b.name()) {
                        return Err(format!(
                            "{ctx}: engine name {:?} does not record the backend",
                            eng.name()
                        ));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Stream-level conformance driver: the golden `CpuPbvdDecoder`
/// decodes the i32 LLR stream once; every matrix cell decodes it
/// through a `StreamCoordinator` with `lanes` pipeline lanes (framing,
/// zero-copy shared dispatch, sharding, splicing, reassembly) and
/// must reproduce the output bit-for-bit with worker stats attached.
/// Cell engines are built through [`DecoderConfig::build_engine`],
/// like the batch-level driver.
pub fn oracle_matrix_stream(
    m: &OracleMatrix,
    label: &str,
    lanes: usize,
    llr: &[i32],
) -> Result<(), String> {
    let want = crate::viterbi::CpuPbvdDecoder::new(m.trellis, m.block, m.depth).decode_stream(llr);
    for &batch in m.batches {
        for (kind, width, backend, workers) in cells(m) {
            let ctx = format!(
                "{} lanes={lanes}",
                cell_label(m, label, batch, kind, width, backend, workers)
            );
            let eng: Arc<dyn DecodeEngine> = cell_config(m, batch, kind, width, backend, workers)
                .build_engine(m.trellis)
                .map_err(|e| format!("{ctx}: build_engine failed: {e}"))?;
            let coord = StreamCoordinator::new(eng, lanes);
            let (got, stats) = coord
                .decode_stream(llr)
                .map_err(|e| format!("{ctx}: stream decode failed: {e}"))?;
            if got != want {
                return Err(format!("{ctx}: stream decode diverged from golden model"));
            }
            let pw = stats
                .per_worker
                .ok_or_else(|| format!("{ctx}: sharded engine reported no worker stats"))?;
            if workers > 0 && pw.workers() != workers {
                return Err(format!("{ctx}: expected {workers} workers, got {}", pw.workers()));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", PropConfig { cases: 10, base_seed: 1 }, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "PBVD_PROP_SEED")]
    fn failing_property_reports_seed() {
        check("fails", PropConfig { cases: 3, base_seed: 2 }, |_rng| {
            Err("nope".into())
        });
    }

    #[test]
    fn matrix_cells_collapse_par_axes() {
        let t = Trellis::preset("k3").unwrap();
        let backends = [AcsBackend::Scalar, AcsBackend::Portable];
        let m = OracleMatrix {
            trellis: &t,
            block: 16,
            depth: 12,
            q: 8,
            engines: &BOTH_ENGINES,
            widths: &BOTH_WIDTHS,
            backends: &backends,
            batches: &[1],
            workers: &[1, 2],
        };
        let cs = cells(&m);
        // par: 2 worker cells; simd: 2 widths * 2 backends * 2 workers
        assert_eq!(cs.len(), 2 + 8);
        assert!(cs.iter().filter(|c| c.0 == EngineKind::Par).count() == 2);
        assert!(cs
            .iter()
            .filter(|c| c.0 == EngineKind::Par)
            .all(|c| c.2.is_none()));
        assert!(cs
            .iter()
            .filter(|c| c.0 == EngineKind::Simd)
            .all(|c| c.2.is_some()));
    }

    #[test]
    fn oracle_matrix_smoke_passes_and_rejects_bad_llr_len() {
        let t = Trellis::preset("k3").unwrap();
        let backends = AcsBackend::available();
        let m = OracleMatrix {
            trellis: &t,
            block: 16,
            depth: 12,
            q: 8,
            engines: &BOTH_ENGINES,
            widths: &BOTH_WIDTHS,
            backends: &backends,
            batches: &[3],
            workers: &[2],
        };
        let per_pb = (16 + 2 * 12) * t.r;
        let mut rng = Xoshiro256::seeded(7);
        oracle_matrix(&m, "smoke", |batch| {
            (0..batch * per_pb)
                .map(|_| ((rng.next_below(256) as i32) - 128) as i8)
                .collect()
        })
        .unwrap();
        let err = oracle_matrix(&m, "short", |_| vec![0i8; 1]).unwrap_err();
        assert!(err.contains("make_llr"), "{err}");
    }

    #[test]
    fn generators_deterministic() {
        let mut a = Xoshiro256::seeded(5);
        let mut b = Xoshiro256::seeded(5);
        assert_eq!(random_bits(&mut a, 100), random_bits(&mut b, 100));
        assert_eq!(random_llrs(&mut a, 50, 127), random_llrs(&mut b, 50, 127));
        let llrs = random_llrs(&mut a, 1000, 31);
        assert!(llrs.iter().all(|&x| (-31..=31).contains(&x)));
    }
}
