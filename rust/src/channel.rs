//! Channel simulation + I/O transforms: BPSK, AWGN, BSC, the q-bit
//! quantizer and the paper's U1/U2 packing schemes (Sec. IV-C).
//!
//! The paper transmits over AWGN, quantizes received soft symbols to
//! q bits, packs `⌊32/q⌋` of them per u32 for the H2D transfer (U1:
//! 4R bytes -> 4R/⌊32/q⌋), and bit-packs decoded output (U2: 4 -> 1/8
//! bytes per bit).  These transforms run in the Rust coordinator's
//! pack/unpack pipeline stages.

use crate::rng::{Normal, Xoshiro256};

// ---------------------------------------------------------------------------
// Modulation.
// ---------------------------------------------------------------------------

/// BPSK map: bit 0 -> +1.0, bit 1 -> -1.0 (paper/CCSDS convention).
pub fn bpsk_modulate(bits: &[u8]) -> Vec<f64> {
    bits.iter().map(|&b| 1.0 - 2.0 * b as f64).collect()
}

/// Hard decision on a soft value under the BPSK map.
#[inline]
pub fn bpsk_hard(y: f64) -> u8 {
    (y < 0.0) as u8
}

// ---------------------------------------------------------------------------
// Channels.
// ---------------------------------------------------------------------------

/// AWGN channel at a given Eb/N0 for a rate-`rate` code.
///
/// With unit-energy BPSK symbols, `sigma^2 = 1 / (2 * rate * 10^(EbN0/10))`.
pub struct AwgnChannel {
    sigma: f64,
    rng: Xoshiro256,
    normal: Normal,
}

impl AwgnChannel {
    /// `ebn0_db` — energy-per-information-bit to noise ratio in dB;
    /// `rate` — code rate (1/R for the codes here); `rng` is split so
    /// the caller's stream stays usable.
    pub fn new(ebn0_db: f64, rate: f64, rng: &mut Xoshiro256) -> Self {
        let ebn0 = 10f64.powf(ebn0_db / 10.0);
        let sigma = (1.0 / (2.0 * rate * ebn0)).sqrt();
        Self {
            sigma,
            rng: rng.split(),
            normal: Normal::new(),
        }
    }

    /// Noise standard deviation.
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Transmit coded bits; returns received soft values (BPSK + noise).
    pub fn transmit(&mut self, coded_bits: &[u8]) -> Vec<f64> {
        coded_bits
            .iter()
            .map(|&b| {
                1.0 - 2.0 * b as f64 + self.sigma * self.normal.sample(&mut self.rng)
            })
            .collect()
    }
}

/// Binary symmetric channel (hard-decision substrate, used in tests and
/// the hard-decision decode extension).
pub struct BscChannel {
    p: f64,
    rng: Xoshiro256,
}

impl BscChannel {
    pub fn new(p: f64, rng: &mut Xoshiro256) -> Self {
        assert!((0.0..=1.0).contains(&p));
        Self { p, rng: rng.split() }
    }

    pub fn transmit(&mut self, coded_bits: &[u8]) -> Vec<u8> {
        coded_bits
            .iter()
            .map(|&b| {
                if self.rng.next_f64() < self.p {
                    b ^ 1
                } else {
                    b
                }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Quantization (Sec. IV-C: q-bit fixed point).
// ---------------------------------------------------------------------------

/// Uniform mid-rise quantizer to signed q-bit integers.
///
/// The decode decision is scale-invariant; only the saturation point
/// matters.  `full_scale` soft units map to the maximum magnitude
/// `2^{q-1} - 1` (default 2.0 ≈ symbol + 3σ at the BERs of interest).
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub q: u32,
    pub full_scale: f64,
}

impl Quantizer {
    pub fn new(q: u32) -> Self {
        assert!((2..=16).contains(&q), "q out of range");
        Self { q, full_scale: 2.0 }
    }

    pub fn with_full_scale(q: u32, full_scale: f64) -> Self {
        assert!(full_scale > 0.0);
        Self { q, full_scale }
    }

    /// Max magnitude representable.
    #[inline]
    pub fn max_mag(&self) -> i32 {
        (1 << (self.q - 1)) - 1
    }

    /// Quantize one soft value.
    #[inline]
    pub fn q1(&self, y: f64) -> i32 {
        let m = self.max_mag();
        let scaled = (y / self.full_scale * m as f64).round();
        scaled.clamp(-(m as f64), m as f64) as i32
    }

    /// Quantize a slice.
    pub fn quantize(&self, soft: &[f64]) -> Vec<i32> {
        soft.iter().map(|&y| self.q1(y)).collect()
    }

    /// Quantize straight to the i8 the artifacts consume (q <= 8).
    pub fn quantize_i8(&self, soft: &[f64]) -> Vec<i8> {
        assert!(self.q <= 8);
        soft.iter().map(|&y| self.q1(y) as i8).collect()
    }
}

// ---------------------------------------------------------------------------
// U1: input symbol packing — ⌊32/q⌋ q-bit values per u32.
// ---------------------------------------------------------------------------

/// Bytes per stored input symbol-component after packing (the paper's
/// U1): `4 / ⌊32/q⌋` (e.g. q=8 -> 1 byte, vs 4 for f32).
pub fn u1_bytes(q: u32) -> f64 {
    4.0 / (32 / q) as f64
}

/// Pack q-bit signed values into u32 words, little-end first.
pub fn pack_llrs(vals: &[i32], q: u32) -> Vec<u32> {
    let per = (32 / q) as usize;
    assert!(per >= 1);
    let mask = (1u32 << q) - 1;
    let mut out = Vec::with_capacity(vals.len().div_ceil(per));
    for chunk in vals.chunks(per) {
        let mut w = 0u32;
        for (i, &v) in chunk.iter().enumerate() {
            w |= ((v as u32) & mask) << (i as u32 * q);
        }
        out.push(w);
    }
    out
}

/// Unpack q-bit signed values (sign-extended) from u32 words.
pub fn unpack_llrs(words: &[u32], q: u32, count: usize) -> Vec<i32> {
    let per = (32 / q) as usize;
    let mask = (1u32 << q) - 1;
    let sign = 1u32 << (q - 1);
    let mut out = Vec::with_capacity(count);
    'outer: for &w in words {
        for i in 0..per {
            if out.len() == count {
                break 'outer;
            }
            let raw = (w >> (i as u32 * q)) & mask;
            let val = if raw & sign != 0 {
                (raw | !mask) as i32
            } else {
                raw as i32
            };
            out.push(val);
        }
    }
    assert_eq!(out.len(), count, "not enough packed words");
    out
}

// ---------------------------------------------------------------------------
// U2: decoded bit packing — 1 bit per bit (paper: char stores 8).
// ---------------------------------------------------------------------------

/// Pack bits (0/1 bytes) into u32 words, bit d -> word d/32 bit d%32
/// (the traceback kernel's output layout).
pub fn pack_bits(bits: &[u8]) -> Vec<u32> {
    let mut out = vec![0u32; bits.len().div_ceil(32)];
    for (d, &b) in bits.iter().enumerate() {
        out[d / 32] |= (b as u32 & 1) << (d % 32);
    }
    out
}

/// Unpack `count` bits from u32 words.
pub fn unpack_bits(words: &[u32], count: usize) -> Vec<u8> {
    (0..count)
        .map(|d| ((words[d / 32] >> (d % 32)) & 1) as u8)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bpsk_map() {
        assert_eq!(bpsk_modulate(&[0, 1, 0]), vec![1.0, -1.0, 1.0]);
        assert_eq!(bpsk_hard(0.3), 0);
        assert_eq!(bpsk_hard(-0.3), 1);
    }

    #[test]
    fn awgn_sigma_formula() {
        let mut rng = Xoshiro256::seeded(1);
        // rate 1/2, Eb/N0 = 3 dB -> sigma^2 = 1/(2*0.5*10^0.3)
        let ch = AwgnChannel::new(3.0, 0.5, &mut rng);
        let expect = (1.0 / 10f64.powf(0.3)).sqrt();
        assert!((ch.sigma() - expect).abs() < 1e-12);
    }

    #[test]
    fn awgn_statistics() {
        let mut rng = Xoshiro256::seeded(2);
        let mut ch = AwgnChannel::new(0.0, 0.5, &mut rng); // sigma = 1
        let zeros = vec![0u8; 100_000];
        let rx = ch.transmit(&zeros);
        let mean: f64 = rx.iter().sum::<f64>() / rx.len() as f64;
        let var: f64 =
            rx.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / rx.len() as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn bsc_flip_rate() {
        let mut rng = Xoshiro256::seeded(3);
        let mut ch = BscChannel::new(0.1, &mut rng);
        let zeros = vec![0u8; 100_000];
        let rx = ch.transmit(&zeros);
        let flips: usize = rx.iter().map(|&b| b as usize).sum();
        let rate = flips as f64 / rx.len() as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn quantizer_saturation_and_symmetry() {
        let q = Quantizer::new(8);
        assert_eq!(q.max_mag(), 127);
        assert_eq!(q.q1(10.0), 127);
        assert_eq!(q.q1(-10.0), -127);
        assert_eq!(q.q1(0.0), 0);
        assert_eq!(q.q1(1.0), -q.q1(-1.0));
        // 3-bit
        let q3 = Quantizer::new(3);
        assert_eq!(q3.max_mag(), 3);
        assert_eq!(q3.q1(2.0), 3);
    }

    #[test]
    fn llr_pack_roundtrip_q8() {
        let vals: Vec<i32> = vec![-127, 127, 0, -1, 1, 64, -64, 5, -5];
        let packed = pack_llrs(&vals, 8);
        assert_eq!(packed.len(), 3); // 9 values / 4 per word
        let got = unpack_llrs(&packed, 8, vals.len());
        assert_eq!(got, vals);
    }

    #[test]
    fn llr_pack_roundtrip_all_q() {
        let mut rng = Xoshiro256::seeded(9);
        for q in [2u32, 3, 4, 5, 6, 8, 10, 16] {
            let m = (1i64 << (q - 1)) - 1;
            let vals: Vec<i32> = (0..1000)
                .map(|_| (rng.next_below((2 * m + 1) as u64) as i64 - m) as i32)
                .collect();
            let packed = pack_llrs(&vals, q);
            assert_eq!(unpack_llrs(&packed, q, vals.len()), vals, "q={q}");
        }
    }

    #[test]
    fn u1_bytes_matches_paper() {
        // q=8, R=2: 4R=8 bytes float -> 2 bytes packed (per symbol pair).
        assert_eq!(u1_bytes(8), 1.0);
        assert_eq!(u1_bytes(4), 0.5);
        assert_eq!(u1_bytes(16), 2.0);
    }

    #[test]
    fn bit_pack_roundtrip() {
        let mut rng = Xoshiro256::seeded(10);
        let bits: Vec<u8> = (0..997).map(|_| rng.next_bit()).collect();
        let packed = pack_bits(&bits);
        assert_eq!(packed.len(), 997usize.div_ceil(32));
        assert_eq!(unpack_bits(&packed, bits.len()), bits);
    }

    #[test]
    fn bit_pack_layout_matches_kernel() {
        // bit d lands at word d/32, bit d%32 — the traceback kernel's
        // packing convention (kernels/traceback.py).
        let mut bits = vec![0u8; 64];
        bits[0] = 1;
        bits[33] = 1;
        let packed = pack_bits(&bits);
        assert_eq!(packed[0], 1);
        assert_eq!(packed[1], 2);
    }
}
