# Convenience targets mirroring .github/workflows/ci.yml — `make ci`
# runs the same sweep locally.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test fmt clippy lint bench-smoke pytest ci artifacts clean

build:
	$(CARGO) build --release --all-targets

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Advisory lint sweep (never fails the ci target, matching the
# continue-on-error lint job in CI).
lint:
	-$(MAKE) fmt
	-$(MAKE) clippy

# cargo runs bench binaries with cwd = rust/; pin reports to the root.
bench-smoke:
	PBVD_BENCH_QUICK=1 PBVD_BENCH_DIR=$(CURDIR) $(CARGO) bench --bench table3
	PBVD_BENCH_QUICK=1 PBVD_BENCH_DIR=$(CURDIR) $(CARGO) bench --bench table4

pytest:
	-$(PYTHON) -m pytest python/tests -q

ci: build test bench-smoke lint pytest
	@echo "local CI sweep complete (lint + pytest are advisory)"

# AOT-lower the Pallas/JAX kernels to HLO text artifacts (needs jax).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

clean:
	$(CARGO) clean
	rm -f BENCH_*.json rust/BENCH_*.json
