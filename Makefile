# Convenience targets mirroring .github/workflows/ci.yml — `make ci`
# runs the same sweep locally.

CARGO ?= cargo
PYTHON ?= python3
# Extra cargo flags; `make ci-native` sets these to enable the AVX2
# intrinsics path of the lane-interleaved SIMD kernel.
CARGO_FLAGS ?=

.PHONY: build test test-portable check-aarch64 doc fmt clippy lint bench-smoke chaos-smoke audit-smoke plan-smoke serve-smoke pytest ci ci-native artifacts clean

build:
	$(CARGO) build --release --all-targets $(CARGO_FLAGS)

test:
	$(CARGO) test -q $(CARGO_FLAGS)

# Gating rustdoc pass (mirrors the docs CI job): broken intra-doc
# links are errors, so the deprecated construction shims provably link
# their DecoderConfig replacements.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps -p pbvd $(CARGO_FLAGS)

# Re-run the suite with the portable lane-chunk ACS backend forced via
# the env override (mirrors the portable-backend CI job): every
# Auto-resolved SIMD engine then runs the portable kernel, still pinned
# bit-identical by the conformance matrices.
test-portable:
	PBVD_SIMD_BACKEND=portable $(CARGO) test -q $(CARGO_FLAGS)

# Advisory cross-compilation of the NEON backend (mirrors the
# cross-aarch64 CI job; needs `rustup target add aarch64-unknown-linux-gnu`).
check-aarch64:
	$(CARGO) check --target aarch64-unknown-linux-gnu -p pbvd --all-targets --features simd-intrinsics

fmt:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Advisory lint sweep (never fails the ci target, matching the
# continue-on-error lint job in CI).
lint:
	-$(MAKE) fmt
	-$(MAKE) clippy

# cargo runs bench binaries with cwd = rust/; pin reports to the root.
# The check_simd_bench step is advisory (leading `-`): it flags the
# lane-interleaved kernel regressing below the scalar baseline, the
# narrow-metric u16 kernel regressing below u32, or full-rate shadow
# auditing costing more than 5% throughput.
bench-smoke:
	PBVD_BENCH_QUICK=1 PBVD_BENCH_DIR=$(CURDIR) $(CARGO) bench --bench table3 $(CARGO_FLAGS)
	PBVD_BENCH_QUICK=1 PBVD_BENCH_DIR=$(CURDIR) $(CARGO) bench --bench table4 $(CARGO_FLAGS)
	PBVD_BENCH_QUICK=1 PBVD_BENCH_DIR=$(CURDIR) $(CARGO) bench --bench cpu_kernels $(CARGO_FLAGS)
	-$(PYTHON) tools/check_simd_bench.py --audit-overhead --plan BENCH_cpu_kernels.json BENCH_table3.json

# Gating chaos conformance suite (mirrors the chaos step of the
# build-test CI job): seeded deterministic fault plans — killed
# connections, dropped result writes, worker panics, overload sheds —
# over real loopback TCP; every stream must finish bit-identical with
# the recovery visible in STATS.
chaos-smoke:
	$(CARGO) test -q --test chaos_serve $(CARGO_FLAGS)

# Gating decode-integrity suite (mirrors the audit step of the
# build-test CI job): full-rate shadow audits across the CPU engine
# matrix with zero false positives, bit-identical path-metric margins,
# a replayable sampling schedule, and typed input hardening.
audit-smoke:
	$(CARGO) test -q --test integrity $(CARGO_FLAGS)

# Gating adaptive-dispatch suite (mirrors the plan step of the
# build-test CI job): performance-history store round-trips, rotation
# and corrupt-line tolerance; empty-history fallback pinning the
# static Auto policy; and the loopback mid-stream live-migration test
# — a seeded history makes the dispatcher re-pick a different engine
# while a stream is in flight, and the decode must stay bit-identical
# to golden.
plan-smoke:
	$(CARGO) test -q --test plan_dispatch $(CARGO_FLAGS)

# Advisory 60 s chaos soak of the `pbvd serve` daemon (mirrors the
# chaos-soak CI job): 4 concurrent client streams decode continuously
# over loopback under a randomized-but-logged probabilistic fault
# plan; every decode is checked bit-identical to golden.  Override the
# duration with PBVD_SOAK_SECS, replay a run with PBVD_CHAOS_SEED.
serve-smoke:
	PBVD_SOAK_SECS=$${PBVD_SOAK_SECS:-60} $(CARGO) test -q --release --test chaos_serve $(CARGO_FLAGS) -- --ignored --nocapture

pytest:
	-$(PYTHON) -m pytest python/tests -q

ci: build test test-portable doc bench-smoke lint pytest
	@echo "local CI sweep complete (lint + pytest are advisory)"

# Native-CPU variant of the CI sweep: tunes codegen to the build
# machine and compiles the explicit AVX2 intrinsics path of the
# lane-interleaved SIMD kernel (runtime-detected, bit-identical).
ci-native:
	RUSTFLAGS="-C target-cpu=native" $(MAKE) ci \
		CARGO_FLAGS="-p pbvd --features simd-intrinsics"

# AOT-lower the Pallas/JAX kernels to HLO text artifacts (needs jax).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

# BENCH_simd_xval.json is a committed cross-validation record, not a
# transient bench artifact — keep it.
clean:
	$(CARGO) clean
	find . -maxdepth 2 -name 'BENCH_*.json' ! -name 'BENCH_simd_xval.json' -delete
