//! §Perf probe: time HLO variants of the forward kernel on the PJRT CPU
//! client.  Usage: perf_probe <file.hlo.txt> <B> <T> <R> [iters]

use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let path = &args[1];
    let (b, t, r): (usize, usize, usize) =
        (args[2].parse()?, args[3].parse()?, args[4].parse()?);
    let iters: usize = args.get(5).map(|s| s.parse()).transpose()?.unwrap_or(5);
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let data = vec![3i8; b * t * r];
    let bytes: Vec<u8> = data.iter().map(|&x| x as u8).collect();
    let mk = || xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8, &[b, t, r], &bytes).unwrap();
    // warmup
    let _ = exe.execute::<xla::Literal>(&[mk()])?;
    let mut best = f64::MAX;
    let mut total = 0.0;
    for _ in 0..iters {
        let lit = mk();
        let t0 = Instant::now();
        let out = exe.execute::<xla::Literal>(&[lit])?;
        let _ = out[0][0].to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
    }
    println!("{path}: mean {:.2} ms, best {:.2} ms", total / iters as f64 * 1e3, best * 1e3);
    Ok(())
}
