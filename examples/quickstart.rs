//! Quickstart: encode a message, push it through an AWGN channel,
//! decode it with the PBVD, and verify the round trip.
//!
//!     cargo run --release --example quickstart
//!
//! Construction goes through the one typed path — `DecoderConfig` —
//! with `EngineKind::Auto`: the PJRT two-kernel engine when
//! `artifacts/` is built, and the (identical-decision) CPU engines
//! otherwise.  The public API is the same either way.

use pbvd::channel::{AwgnChannel, Quantizer};
use pbvd::config::{DecoderConfig, EngineKind};
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::runtime::Registry;
use pbvd::trellis::Trellis;

fn main() -> anyhow::Result<()> {
    // 1. The code: CCSDS (2,1,7) — the paper's primary code.
    let trellis = Trellis::preset("ccsds_k7")?;
    println!("code: K={} R={} ({} states, {} butterfly groups)",
             trellis.k, trellis.r, trellis.n_states, trellis.n_groups);

    // 2. A payload.
    let mut rng = Xoshiro256::seeded(42);
    let payload: Vec<u8> = (0..50_000).map(|_| rng.next_bit()).collect();

    // 3. Encode, modulate, add noise at 4 dB Eb/N0, quantize to 8 bits.
    let mut encoder = ConvEncoder::new(&trellis);
    let coded = encoder.encode(&payload);
    let mut channel = AwgnChannel::new(4.0, 1.0 / trellis.r as f64, &mut rng);
    let received = channel.transmit(&coded);
    let llr = Quantizer::new(8).quantize(&received);

    // 4. Decode with the streaming coordinator.  One config describes
    //    the whole realization; `build_coordinator` is the single
    //    construction path for every engine and frontend (as of 0.4
    //    the old free functions — `best_available_coordinator`,
    //    `cpu_engine_for_workers*` — are gone).
    let registry = Registry::open_default().ok();
    let config = DecoderConfig::new("ccsds_k7")
        .batch(32)   // PBs per engine call (N_t)
        .block(64)   // decode block D
        .depth(42)   // decoding depth L
        .workers(0)  // CPU fallback: sharded pool sized to the machine
        .lanes(3)    // pipeline lanes (N_s streams)
        .engine(EngineKind::Auto); // PJRT if artifacts exist, else CPU
    let coordinator = config.build_coordinator(registry.as_ref())?;
    println!("engine: {}", coordinator.engine.name());
    let (decoded, stats) = coordinator.decode_stream(&llr)?;

    // 5. Verify.
    let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
    println!("decoded {} bits in {:.1} ms ({:.2} Mbps)",
             stats.n_bits, stats.wall.as_secs_f64() * 1e3, stats.throughput_mbps());
    println!("bit errors: {errors} (BER {:.2e})", errors as f64 / payload.len() as f64);
    assert!(errors < 5, "unexpected error rate at 4 dB");
    println!("quickstart OK");
    Ok(())
}
