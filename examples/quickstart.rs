//! Quickstart: encode a message, push it through an AWGN channel,
//! decode it with the PBVD, and verify the round trip.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the PJRT two-kernel engine when `artifacts/` is built, and the
//! (identical-decision) CPU engine otherwise — the public API is the
//! same either way.

use pbvd::channel::{AwgnChannel, Quantizer};
use pbvd::coordinator::best_available_coordinator;
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::runtime::Registry;
use pbvd::trellis::Trellis;

fn main() -> anyhow::Result<()> {
    // 1. The code: CCSDS (2,1,7) — the paper's primary code.
    let trellis = Trellis::preset("ccsds_k7")?;
    println!("code: K={} R={} ({} states, {} butterfly groups)",
             trellis.k, trellis.r, trellis.n_states, trellis.n_groups);

    // 2. A payload.
    let mut rng = Xoshiro256::seeded(42);
    let payload: Vec<u8> = (0..50_000).map(|_| rng.next_bit()).collect();

    // 3. Encode, modulate, add noise at 4 dB Eb/N0, quantize to 8 bits.
    let mut encoder = ConvEncoder::new(&trellis);
    let coded = encoder.encode(&payload);
    let mut channel = AwgnChannel::new(4.0, 1.0 / trellis.r as f64, &mut rng);
    let received = channel.transmit(&coded);
    let llr = Quantizer::new(8).quantize(&received);

    // 4. Decode with the streaming coordinator (PJRT if available).
    let registry = Registry::open_default().ok();
    let coordinator = best_available_coordinator(
        registry.as_ref(), &trellis,
        /*batch=*/ 32, /*block D=*/ 64, /*depth L=*/ 42, /*lanes=*/ 3,
        /*workers=*/ 0, // CPU fallback: sharded pool sized to the machine
    )?;
    println!("engine: {}", coordinator.engine.name());
    let (decoded, stats) = coordinator.decode_stream(&llr)?;

    // 5. Verify.
    let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
    println!("decoded {} bits in {:.1} ms ({:.2} Mbps)",
             stats.n_bits, stats.wall.as_secs_f64() * 1e3, stats.throughput_mbps());
    println!("bit errors: {errors} (BER {:.2e})", errors as f64 / payload.len() as f64);
    assert!(errors < 5, "unexpected error rate at 4 dB");
    println!("quickstart OK");
    Ok(())
}
