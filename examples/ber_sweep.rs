//! Fig. 4 regeneration: BER vs Eb/N0 for several decoding depths L.
//!
//!     cargo run --release --example ber_sweep          # quick preset
//!     cargo run --release --example ber_sweep -- full  # paper-grade
//!
//! Prints a CSV-ish table (one series per L, plus uncoded BPSK and the
//! truncation-free block VA as references).  EXPERIMENTS.md §Fig4
//! archives a run.

use pbvd::ber::{measure_ber, uncoded_bpsk_ber, BerConfig};
use pbvd::trellis::Trellis;
use pbvd::viterbi::CpuPbvdDecoder;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().nth(1).as_deref() == Some("full");
    let trellis = Trellis::preset("ccsds_k7")?;
    let depths = [7usize, 14, 21, 28, 42, 63];
    let ebn0: Vec<f64> = if full {
        (0..=12).map(|i| i as f64 * 0.5).collect()
    } else {
        vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]
    };
    let block = 256; // paper uses 512; "less important factor" (Sec. V)
    let cfg = BerConfig {
        bits_per_trial: 8192,
        target_errors: if full { 300 } else { 60 },
        max_bits: if full { 20_000_000 } else { 600_000 },
        q: 8,
        threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        seed: 2016,
    };
    println!("# Fig. 4 — BER of (2,1,7) CCSDS code, D={block}, 8-bit quantization");
    print!("ebn0_db,uncoded");
    for l in depths {
        print!(",L{l}");
    }
    println!();
    for &e in &ebn0 {
        print!("{e:.1},{:.3e}", uncoded_bpsk_ber(e));
        for &l in &depths {
            let dec = CpuPbvdDecoder::new(&trellis, block, l);
            let p = measure_ber(&trellis, &dec, e, &cfg);
            print!(",{:.3e}", p.ber());
        }
        println!();
    }
    eprintln!("expected: BER improves with L and saturates near L=42 (~6K).");
    Ok(())
}
