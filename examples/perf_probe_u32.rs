//! §Perf probe for u32-input HLOs (traceback variants).
use anyhow::Result;
use std::time::Instant;
fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let path = &args[1];
    let (b, t, w): (usize, usize, usize) =
        (args[2].parse()?, args[3].parse()?, args[4].parse()?);
    let iters: usize = args.get(5).map(|s| s.parse()).transpose()?.unwrap_or(5);
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let data = vec![0x5A5A_5A5Au32; b * t * w];
    let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
    let mk = || xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32, &[b, t, w], &bytes).unwrap();
    let _ = exe.execute::<xla::Literal>(&[mk()])?;
    let mut total = 0.0;
    for _ in 0..iters {
        let lit = mk();
        let t0 = Instant::now();
        let out = exe.execute::<xla::Literal>(&[lit])?;
        let _ = out[0][0].to_literal_sync()?;
        total += t0.elapsed().as_secs_f64();
    }
    println!("{path}: mean {:.2} ms", total / iters as f64 * 1e3);
    Ok(())
}
