//! Generality demo (the Sec. I reconfigurability claim): the same
//! public API decodes four different standards' convolutional codes —
//! constraint lengths 3..9 and rates 1/2, 1/3 — switching AOT
//! artifacts per code.  Each realization is one `DecoderConfig`; the
//! factory picks PJRT or the CPU engines per code.
//!
//!     cargo run --release --example multi_code

use pbvd::channel::{AwgnChannel, Quantizer};
use pbvd::config::{DecoderConfig, EngineKind};
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::runtime::Registry;
use pbvd::trellis::Trellis;

fn main() -> anyhow::Result<()> {
    let registry = Registry::open_default().ok();
    // (code, batch, block, depth) — matching the shipped artifacts
    let configs = [
        ("k3", 16usize, 32usize, 15usize, "textbook (2,1,3) [7,5]"),
        ("k5", 32, 64, 25, "(2,1,5) [23,35]"),
        ("ccsds_k7", 32, 64, 42, "CCSDS (2,1,7) [171,133]"),
        ("k9", 16, 64, 45, "(2,1,9) [561,753] (IS-95 style)"),
        ("r3_k7", 32, 64, 42, "(3,1,7) [133,145,175] rate 1/3"),
    ];
    let mut rng = Xoshiro256::seeded(99);
    println!("{:<10} {:<28} {:>7} {:>9} {:>8} {:>10}",
             "code", "description", "states", "groups", "errors", "T/P Mbps");
    for (name, batch, block, depth, desc) in configs {
        let trellis = Trellis::preset(name)?;
        let coord = DecoderConfig::new(name)
            .batch(batch)
            .block(block)
            .depth(depth)
            .workers(4)
            .lanes(2)
            .engine(EngineKind::Auto)
            .build_coordinator(registry.as_ref())?;
        let n = 40_000usize;
        let payload: Vec<u8> = (0..n).map(|_| rng.next_bit()).collect();
        let mut enc = ConvEncoder::new(&trellis);
        let coded = enc.encode(&payload);
        let mut ch = AwgnChannel::new(5.0, 1.0 / trellis.r as f64, &mut rng);
        let soft = ch.transmit(&coded);
        let llr = Quantizer::new(8).quantize(&soft);
        let (out, stats) = coord.decode_stream(&llr)?;
        let errors = out.iter().zip(&payload).filter(|(a, b)| a != b).count();
        println!("{:<10} {:<28} {:>7} {:>9} {:>8} {:>10.2}",
                 name, desc, trellis.n_states, trellis.n_groups, errors,
                 stats.throughput_mbps());
    }
    println!("\nmulti_code OK — one decoder, five codes, one construction path.");
    Ok(())
}
