//! End-to-end SDR-style driver (the DESIGN.md §3 validation workload):
//! a long continuous bitstream is encoded, impaired by AWGN, framed
//! into parallel blocks and decoded by the full three-layer stack
//! (Rust coordinator -> PJRT -> AOT Pallas kernels), comparing lane
//! counts and reporting throughput/latency like a serving benchmark.
//!
//!     cargo run --release --example sdr_stream [n_bits] [ebn0_db]
//!
//! Results for the default configuration are recorded in
//! EXPERIMENTS.md §End-to-end.

use pbvd::channel::{AwgnChannel, Quantizer};
use pbvd::config::{DecoderConfig, EngineKind, PjrtVariant};
use pbvd::coordinator::{DecodeEngine, StreamCoordinator};
use pbvd::encoder::ConvEncoder;
use pbvd::rng::Xoshiro256;
use pbvd::runtime::Registry;
use pbvd::trellis::Trellis;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_bits: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(1_000_000);
    let ebn0: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(4.5);

    let trellis = Trellis::preset("ccsds_k7")?;
    let mut rng = Xoshiro256::seeded(0x5D12);

    // --- transmit side -----------------------------------------------------
    println!("== transmit: {n_bits} info bits, CCSDS (2,1,7), BPSK, AWGN {ebn0} dB");
    let t0 = Instant::now();
    let payload: Vec<u8> = (0..n_bits).map(|_| rng.next_bit()).collect();
    let mut enc = ConvEncoder::new(&trellis);
    let coded = enc.encode(&payload);
    let mut ch = AwgnChannel::new(ebn0, 0.5, &mut rng);
    let soft = ch.transmit(&coded);
    let llr = Quantizer::new(8).quantize(&soft);
    println!("   tx pipeline: {:.1} ms ({} coded bits)", t0.elapsed().as_secs_f64() * 1e3, coded.len());

    // --- receive side ------------------------------------------------------
    let reg = Registry::open_default().ok();
    // paper-shape geometry when available, small otherwise — every
    // candidate realization is one DecoderConfig through the unified
    // factory
    let geometries = [(64usize, 512usize, 42usize), (32, 64, 42)];
    let mut engine: Option<Arc<dyn DecodeEngine>> = None;
    if let Some(reg) = reg.as_ref() {
        for (b, d, l) in geometries {
            let cfg = DecoderConfig::new("ccsds_k7")
                .batch(b)
                .block(d)
                .depth(l)
                .engine(EngineKind::Pjrt(PjrtVariant::Two));
            if let Ok(e) = cfg.build_engine_with(&trellis, Some(reg)) {
                engine = Some(e);
                break;
            }
        }
    }
    let engine = match engine {
        Some(e) => e,
        None => {
            eprintln!("   (artifacts missing: falling back to sharded CPU engine)");
            DecoderConfig::new("ccsds_k7")
                .batch(64)
                .block(512)
                .depth(42)
                .workers(0)
                .engine(EngineKind::Par)
                .build_engine(&trellis)?
        }
    };
    println!("== decode engine: {}", engine.name());

    println!("\n{:>5} | {:>10} | {:>9} | {:>9} | {:>8} | {:>8}",
             "lanes", "wall ms", "T/P Mbps", "S_k Mbps", "errors", "BER");
    let mut rows = Vec::new();
    for lanes in [1usize, 2, 3, 4] {
        let coord = StreamCoordinator::new(Arc::clone(&engine), lanes);
        let t0 = Instant::now();
        let (decoded, stats) = coord.decode_stream(&llr)?;
        let wall = t0.elapsed();
        let errors = decoded.iter().zip(&payload).filter(|(a, b)| a != b).count();
        let tp = n_bits as f64 / wall.as_secs_f64() / 1e6;
        println!("{:>5} | {:>10.1} | {:>9.2} | {:>9.2} | {:>8} | {:>8.1e}",
                 lanes, wall.as_secs_f64() * 1e3, tp,
                 stats.kernel_throughput_mbps(), errors,
                 errors as f64 / n_bits as f64);
        rows.push((lanes, tp));
    }

    // multi-lane overlap (the CUDA-streams claim; flat on 1-core boxes)
    let tp1 = rows[0].1;
    let best = rows.iter().map(|r| r.1).fold(0.0f64, f64::max);
    println!("\nlane overlap speedup: x{:.2}", best / tp1);

    // serving-style latency report for the last configuration
    let coord = StreamCoordinator::new(Arc::clone(&engine), 3);
    let (_, _) = coord.decode_stream(&llr)?;
    println!("batch latency: {}", coord.batch_latency.summary());
    println!("sdr_stream OK");
    Ok(())
}
