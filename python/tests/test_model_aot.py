"""L2 model variants + AOT lowering: shapes, manifest, HLO round-trip.

The HLO round-trip test compiles the emitted HLO text back through the
local XLA client and executes it — the same path the Rust runtime takes
(text -> HloModuleProto -> compile -> execute) — proving the artifact is
self-contained and numerically identical to the jit path.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.trellis import build_trellis
from compile.kernels import ref

CFG = model.DecodeConfig("ccsds_k7", batch=32, block=64, depth=42)


def make_batch(cfg, seed=0, noise=0.3):
    t = build_trellis(cfg.code)
    rng = np.random.default_rng(seed)
    T = cfg.total
    llrs = np.zeros((cfg.batch, T, t.R), dtype=np.int8)
    bits = np.zeros((cfg.batch, T), dtype=np.int64)
    for b in range(cfg.batch):
        x = rng.integers(0, 2, T)
        cw = t.encode(x)
        y = (1 - 2 * cw) * 8 + rng.normal(0, noise * 8, cw.shape)
        llrs[b] = np.clip(y, -127, 127).astype(np.int8)
        bits[b] = x
    return llrs, bits


@pytest.mark.parametrize("variant", list(model.VARIANTS))
def test_variant_shapes(variant):
    fn, t = model.VARIANTS[variant](CFG)
    ins = model.input_spec(CFG, variant)
    outs = model.output_spec(CFG, variant)
    args = [jnp.zeros(s.shape, s.dtype) for s in ins]
    res = fn(*args)
    if not isinstance(res, (tuple, list)):
        res = (res,)
    assert len(res) == len(outs)
    for r, (shape, dt) in zip(res, outs):
        assert tuple(r.shape) == tuple(shape)


def test_two_kernel_equals_fused():
    llrs, _ = make_batch(CFG, seed=3)
    x = jnp.asarray(llrs)
    fwd, _ = model.make_forward_fn(CFG)
    tb, _ = model.make_traceback_fn(CFG)
    fused, _ = model.make_decode_fused_fn(CFG)
    sp, _pm = fwd(x)
    out2 = np.asarray(tb(sp))
    out1 = np.asarray(fused(x))
    assert np.array_equal(out1, out2)


def test_orig_decodes_same_bits():
    """The original-decoder baseline must be functionally identical
    (same decisions), only its I/O format and BM math differ."""
    llrs, _ = make_batch(CFG, seed=4)
    fused, t = model.make_decode_fused_fn(CFG)
    orig, _ = model.make_decode_orig_fn(CFG)
    packed = np.asarray(fused(jnp.asarray(llrs)))
    unpacked = np.asarray(orig(jnp.asarray(llrs, dtype=jnp.float32)))
    assert np.array_equal(
        ref.unpack_bits_np(packed, CFG.block), unpacked.astype(np.int8)
    )


# ---------------------------------------------------------------------------
# AOT: HLO text round-trip through the XLA client (the Rust path).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["forward", "traceback", "fused", "orig"])
def test_hlo_text_lowering_nonempty(variant):
    text = aot.lower_variant(CFG, variant)
    assert "ENTRY" in text
    assert "HloModule" in text
    # while-loop (scan) present, no python callbacks leaked into HLO
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_manifest_quick(tmp_path):
    m = aot.build_all(str(tmp_path), quick=True)
    assert (tmp_path / "manifest.json").exists()
    names = {e["name"] for e in m["entries"]}
    assert "forward_ccsds_k7_b32_d64_l42" in names
    for e in m["entries"]:
        assert (tmp_path / e["file"]).exists()
        assert e["total"] == e["block"] + 2 * e["depth"]
    # trellis JSON exports exist and agree with live tables
    for code, info in m["codes"].items():
        data = json.loads((tmp_path / info["file"]).read_text())
        t = build_trellis(code)
        assert data["n_groups"] == t.n_groups
        assert data["next_state"] == t.next_state.tolist()


@pytest.mark.parametrize("variant", ["forward", "traceback", "fused", "orig"])
def test_hlo_text_parses_back(variant):
    """The HLO text must parse back into an HloModule with the declared
    entry shapes — the same parse the Rust runtime performs.  (The full
    execute round-trip is covered by the cargo integration test
    ``rust/tests/runtime_roundtrip.rs``, which runs the actual consumer,
    xla_extension 0.5.1.)"""
    text = aot.lower_variant(CFG, variant)
    parsed = xc._xla.hlo_module_from_text(text)
    rendered = parsed.to_string()
    assert "ENTRY" in rendered
    # Trellis tables are closed over as HLO constants after jit lowering,
    # so the entry signature has exactly the user inputs.
    ins = model.input_spec(CFG, variant)
    assert rendered.count("parameter(") >= len(ins)


def test_jit_equals_eager():
    """jit-compiled decode equals eager decode (lowering is faithful)."""
    llrs, _ = make_batch(CFG, seed=5)
    fused, _ = model.make_decode_fused_fn(CFG)
    eager = np.asarray(fused(jnp.asarray(llrs)))
    jitted = np.asarray(jax.jit(fused)(jnp.asarray(llrs)))
    assert np.array_equal(eager, jitted)
