"""Trellis construction and group classification (paper Sec. III-B)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.trellis import (
    CODES, Trellis, build_trellis, encoder_output, parity, table2,
)


@pytest.fixture(scope="module")
def ccsds() -> Trellis:
    return build_trellis("ccsds_k7")


# ---------------------------------------------------------------------------
# Table II — exact reproduction.
# ---------------------------------------------------------------------------

PAPER_TABLE2 = [
    ("00", "11", "11", "00",
     [0, 1, 4, 5, 24, 25, 28, 29, 42, 43, 46, 47, 50, 51, 54, 55]),
    ("01", "10", "10", "01",
     [2, 3, 6, 7, 26, 27, 30, 31, 40, 41, 44, 45, 48, 49, 52, 53]),
    ("11", "00", "00", "11",
     [8, 9, 12, 13, 16, 17, 20, 21, 34, 35, 38, 39, 58, 59, 62, 63]),
    ("10", "01", "01", "10",
     [10, 11, 14, 15, 18, 19, 22, 23, 32, 33, 36, 37, 56, 57, 60, 61]),
]


def test_table2_exact(ccsds):
    rows = table2(ccsds)
    assert len(rows) == 4
    for row, (a, b, g, th, states) in zip(rows, PAPER_TABLE2):
        assert row["alpha"] == a
        assert row["beta"] == b
        assert row["gamma"] == g
        assert row["theta"] == th
        assert row["states"] == states


def test_ccsds_dimensions(ccsds):
    assert ccsds.K == 7 and ccsds.R == 2
    assert ccsds.n_states == 64
    assert ccsds.n_groups == 4          # 2^R groups (Sec. V)
    assert ccsds.n_sp_words == 4        # 16 bits used per word
    assert ccsds.words_per_group == 1


def test_generators_match_paper(ccsds):
    # g1 = 1111001, g2 = 1011011 (Sec. V)
    assert format(ccsds.polys[0], "07b") == "1111001"
    assert format(ccsds.polys[1], "07b") == "1011011"


# ---------------------------------------------------------------------------
# Structural invariants (all registered codes).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("code", list(CODES))
def test_butterfly_structure(code):
    t = build_trellis(code)
    N = t.n_states
    for j in range(N // 2):
        # both butterfly sources reach exactly {j, j + N/2}
        assert t.next_state[2 * j, 0] == j
        assert t.next_state[2 * j + 1, 0] == j
        assert t.next_state[2 * j, 1] == j + N // 2
        assert t.next_state[2 * j + 1, 1] == j + N // 2


@pytest.mark.parametrize("code", list(CODES))
def test_group_label_relations(code):
    """Eqs. (4)-(6): beta/gamma/theta are fixed XOR offsets of alpha."""
    t = build_trellis(code)
    msb = 0
    lsb = 0
    for p in t.polys:
        msb = (msb << 1) | ((p >> (t.K - 1)) & 1)
        lsb = (lsb << 1) | (p & 1)
    for w in range(t.n_groups):
        a, b, g, th = (int(x) for x in t.group_labels[w])
        assert b == a ^ msb
        assert g == a ^ lsb
        assert th == a ^ msb ^ lsb


@pytest.mark.parametrize("code", list(CODES))
def test_groups_partition_butterflies(code):
    t = build_trellis(code)
    seen = sorted(j for grp in t.group_bflys for j in grp)
    assert seen == list(range(t.n_states // 2))
    assert t.n_groups <= 1 << t.R
    # butterflies in a group share alpha
    for w, grp in enumerate(t.group_bflys):
        for j in grp:
            assert t.bfly_alpha[j] == t.group_alpha[w]


@pytest.mark.parametrize("code", list(CODES))
def test_sp_packing_bijective(code):
    """Every target state owns exactly one (word, bit) slot."""
    t = build_trellis(code)
    slots = set()
    for s in range(t.n_states):
        w, b = int(t.sp_word[s]), int(t.sp_bit[s])
        assert 0 <= w < t.n_sp_words and 0 <= b < 32
        slots.add((w, b))
        assert t.word_states[w, b] == s
    assert len(slots) == t.n_states


@pytest.mark.parametrize("code", list(CODES))
def test_encoder_output_consistency(code):
    """output[] table matches eq. (2) recomputed independently."""
    t = build_trellis(code)
    for d in range(t.n_states):
        for x in (0, 1):
            reg = (x << (t.K - 1)) | d
            cw = 0
            for p in t.polys:
                cw = (cw << 1) | (bin(reg & p).count("1") & 1)
            assert t.output[d, x] == cw


def test_encode_known_vector():
    """Classic (2,1,3) [7,5] code: input 1011 from state 0 ->
    11 10 00 01 (standard textbook vector)."""
    t = build_trellis("k3")
    out = t.encode(np.array([1, 0, 1, 1]))
    expected = np.array([[1, 1], [1, 0], [0, 0], [0, 1]])
    assert np.array_equal(out, expected)


# ---------------------------------------------------------------------------
# Hypothesis: classification laws hold for random codes.
# ---------------------------------------------------------------------------

@st.composite
def random_code(draw):
    K = draw(st.integers(min_value=3, max_value=8))
    R = draw(st.integers(min_value=2, max_value=3))
    polys = []
    for _ in range(R):
        # force the MSB and LSB taps to be free bits (any value)
        p = draw(st.integers(min_value=1, max_value=(1 << K) - 1))
        polys.append(p)
    return K, polys


@given(random_code())
@settings(max_examples=40, deadline=None)
def test_group_sharing_property(code):
    """For any polynomials: butterflies with equal alpha have identical
    (alpha, beta, gamma, theta) label quadruples — the theorem behind
    the paper's 2^{R+2} BM bound."""
    K, polys = code
    N = 1 << (K - 1)
    by_alpha = {}
    for j in range(N // 2):
        a = encoder_output(polys, K, 2 * j, 0)
        b = encoder_output(polys, K, 2 * j, 1)
        g = encoder_output(polys, K, 2 * j + 1, 0)
        th = encoder_output(polys, K, 2 * j + 1, 1)
        quad = (a, b, g, th)
        if a in by_alpha:
            assert by_alpha[a] == quad
        else:
            by_alpha[a] = quad
    assert len(by_alpha) <= 1 << len(polys)


@given(st.integers(min_value=0, max_value=(1 << 20) - 1))
@settings(max_examples=50, deadline=None)
def test_parity(x):
    assert parity(x) == bin(x).count("1") % 2
