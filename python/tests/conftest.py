"""Collection guard: skip modules whose dependencies are missing.

CI runs `pytest python/tests` on machines that may not have jax (the
Rust workspace builds and tests without it), so jax-dependent modules
are excluded from collection rather than erroring at import time.
"""

import importlib.util


def _missing(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is None
    except (ImportError, ValueError):
        return True


collect_ignore = []

# Every module here needs numpy + hypothesis.
if _missing("numpy") or _missing("hypothesis"):
    collect_ignore = ["test_trellis.py", "test_kernels.py", "test_model_aot.py"]
# The kernel/AOT layers additionally need jax + jaxlib.
elif _missing("jax") or _missing("jaxlib"):
    collect_ignore = ["test_kernels.py", "test_model_aot.py"]
    print("conftest: jax not importable -> skipping kernel/AOT test modules")
