"""Collection guard: skip modules whose dependencies are missing.

CI runs `pytest python/tests` on machines that may not have jax (the
Rust workspace builds and tests without it), so jax-dependent modules
are excluded from collection rather than erroring at import time.
"""

import importlib.util
import os
import sys

# Make `from compile... import ...` resolve regardless of invocation
# directory (CI and `make pytest` run from the workspace root, local
# runs often from python/).
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))


def _missing(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is None
    except (ImportError, ValueError):
        return True


collect_ignore = []

# test_simd_lockstep_port only needs numpy; the rest also need hypothesis.
if _missing("numpy"):
    collect_ignore = [
        "test_trellis.py",
        "test_kernels.py",
        "test_model_aot.py",
        "test_simd_lockstep_port.py",
    ]
else:
    if _missing("hypothesis"):
        collect_ignore += ["test_trellis.py", "test_kernels.py", "test_model_aot.py"]
    # The kernel/AOT layers additionally need jax + jaxlib.
    elif _missing("jax") or _missing("jaxlib"):
        collect_ignore += ["test_kernels.py", "test_model_aot.py"]
        print("conftest: jax not importable -> skipping kernel/AOT test modules")
