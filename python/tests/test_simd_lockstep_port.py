"""Pure-python port of the Rust lane-interleaved SIMD ACS kernel
(rust/src/simd.rs) validated against the golden PBVD forward/traceback.

This is the executable specification of the lockstep algorithm: the
Gray-code interleaved branch-metric fill, the `[state][lane]` SoA
butterfly stage with u8 lane-mask decisions, and the per-lane
traceback.  The Rust property tests (rust/tests/simd_engine.rs) pin
the real kernel against the real golden decoder; this module keeps the
algorithm itself regression-tested from the Python side (it needs only
numpy, so it runs in CI even without jax).
"""

import random

import numpy as np
import pytest

from compile.trellis import build_trellis

LANES = 8
U32 = 0xFFFFFFFF


# ---------------------------------------------------------------------------
# Golden model (mirrors rust/src/viterbi.rs CpuPbvdDecoder).
# ---------------------------------------------------------------------------


def golden_forward(t, llr, block, depth):
    r, n, half = t.R, t.n_states, t.n_states // 2
    tt = block + 2 * depth
    assert len(llr) == tt * r
    pm = [0] * n
    sel_rows = []
    for s in range(tt):
        llr_s = llr[s * r:(s + 1) * r]
        bm = []
        for c in range(1 << r):
            acc = 0
            for ri in range(r):
                bit = (c >> (r - 1 - ri)) & 1
                acc += llr_s[ri] * (2 * bit - 1)
            bm.append(acc)
        new_pm = [0] * n
        sel = [0] * n
        for j in range(half):
            pe, po = pm[2 * j], pm[2 * j + 1]
            a = pe + bm[t.cw_top0[j]]
            b = po + bm[t.cw_top1[j]]
            sel[j] = 1 if b < a else 0
            new_pm[j] = min(a, b)
            a2 = pe + bm[t.cw_bot0[j]]
            b2 = po + bm[t.cw_bot1[j]]
            sel[j + half] = 1 if b2 < a2 else 0
            new_pm[j + half] = min(a2, b2)
        mn = min(new_pm)
        pm = [x - mn for x in new_pm]
        sel_rows.append(sel)
    return sel_rows, pm


def golden_traceback(t, sel_rows, block, depth, start_state):
    d, l = block, depth
    v = t.K - 1
    mask = (1 << (v - 1)) - 1
    state = start_state
    out = [0] * d
    for s in range(d + 2 * l - 1, l - 1, -1):
        if s <= d + l - 1:
            out[s - l] = (state >> (v - 1)) & 1
        bit = sel_rows[s][state]
        state = 2 * (state & mask) + bit
    return out


# ---------------------------------------------------------------------------
# Lane-interleaved kernel port (mirrors rust/src/simd.rs).
# ---------------------------------------------------------------------------


def gray_walk(r):
    """(codeword, llr_index, bit_now_set) per step — par.rs::gray_walk."""
    g = 0
    for i in range(1, 1 << (r - 1)):
        p = (i & -i).bit_length() - 1
        g ^= 1 << p
        yield g, r - 1 - p, (g >> p) & 1 == 1


def fill_bm_lanes(stage_vals, r):
    """stage_vals: [R][LANES] ints -> bm [2^R][LANES] u32 (R*128 shift)."""
    off = r * 128
    size = 1 << r
    mask = size - 1
    bm = [[0] * LANES for _ in range(size)]
    acc = [-sum(stage_vals[ri][lane] for ri in range(r)) for lane in range(LANES)]
    for lane in range(LANES):
        bm[0][lane] = (off + acc[lane]) & U32
        bm[mask][lane] = (off - acc[lane]) & U32
    for g, ri, set_ in gray_walk(r):
        for lane in range(LANES):
            d = 2 * stage_vals[ri][lane]
            acc[lane] += d if set_ else -d
            bm[g][lane] = (off + acc[lane]) & U32
            bm[mask ^ g][lane] = (off - acc[lane]) & U32
    return bm


def simd_forward(t, lane_llrs, block, depth):
    """Returns (dw [T][N] u8 lane masks, pm [N][LANES] u32)."""
    r, n, half = t.R, t.n_states, t.n_states // 2
    tt = block + 2 * depth
    pm = [[0] * LANES for _ in range(n)]
    dw = []
    for s in range(tt):
        stage_vals = [[lane_llrs[lane][s * r + ri] for lane in range(LANES)]
                      for ri in range(r)]
        bm = fill_bm_lanes(stage_vals, r)
        new_pm = [[0] * LANES for _ in range(n)]
        dw_row = [0] * n
        minv = [U32] * LANES
        for j in range(half):
            pe, po = pm[2 * j], pm[2 * j + 1]
            bt0, bt1 = bm[t.cw_top0[j]], bm[t.cw_top1[j]]
            bb0, bb1 = bm[t.cw_bot0[j]], bm[t.cw_bot1[j]]
            sel_top = sel_bot = 0
            for lane in range(LANES):
                a = (pe[lane] + bt0[lane]) & U32
                b = (po[lane] + bt1[lane]) & U32
                m = min(a, b)
                sel_top |= (1 if b < a else 0) << lane
                new_pm[j][lane] = m
                minv[lane] = min(minv[lane], m)
                a2 = (pe[lane] + bb0[lane]) & U32
                b2 = (po[lane] + bb1[lane]) & U32
                m2 = min(a2, b2)
                sel_bot |= (1 if b2 < a2 else 0) << lane
                new_pm[j + half][lane] = m2
                minv[lane] = min(minv[lane], m2)
            dw_row[j] = sel_top
            dw_row[j + half] = sel_bot
        for st in range(n):
            for lane in range(LANES):
                new_pm[st][lane] = (new_pm[st][lane] - minv[lane]) & U32
        pm = new_pm
        dw.append(dw_row)
    return dw, pm


def simd_traceback(t, dw, lane, block, depth, start_state):
    d, l = block, depth
    v = t.K - 1
    mask = (1 << (v - 1)) - 1
    state = start_state
    out = [0] * d
    for s in range(d + 2 * l - 1, l - 1, -1):
        if s <= d + l - 1:
            out[s - l] = (state >> (v - 1)) & 1
        bit = (dw[s][state] >> lane) & 1
        state = 2 * (state & mask) + bit
    return out


# ---------------------------------------------------------------------------
# Tests.
# ---------------------------------------------------------------------------


def test_gray_walk_is_a_single_bit_gray_sequence():
    for r in (1, 2, 3, 4):
        seen = {0}
        g_prev = 0
        for g, ri, set_ in gray_walk(r):
            diff = g ^ g_prev
            assert diff.bit_count() == 1, "one bit flips per step"
            p = diff.bit_length() - 1
            assert ri == r - 1 - p
            assert set_ == bool((g >> p) & 1)
            assert g < (1 << (r - 1)), "stays in the lower half (MSB clear)"
            seen.add(g)
            g_prev = g
        assert seen == set(range(1 << (r - 1))), "visits every lower codeword"


def test_interleaved_fill_matches_direct_correlation():
    rnd = random.Random(7)
    for r in (1, 2, 3):
        for _ in range(20):
            stage_vals = [[rnd.randint(-128, 127) for _ in range(LANES)]
                          for _ in range(r)]
            bm = fill_bm_lanes(stage_vals, r)
            off = r * 128
            for c in range(1 << r):
                for lane in range(LANES):
                    acc = sum(stage_vals[ri][lane] * (2 * ((c >> (r - 1 - ri)) & 1) - 1)
                              for ri in range(r))
                    assert bm[c][lane] == (off + acc) & U32, f"r={r} c={c} lane={lane}"


@pytest.mark.parametrize("code", ["k3", "ccsds_k7"])
def test_lockstep_kernel_bit_identical_to_golden(code):
    t = build_trellis(code)
    block, depth = 24, 6 * t.K
    tt = block + 2 * depth
    rnd = random.Random(0xB1F)
    for _ in range(2):
        lane_llrs = [[rnd.randint(-128, 127) for _ in range(tt * t.R)]
                     for _ in range(LANES)]
        dw, pm = simd_forward(t, lane_llrs, block, depth)
        for lane in range(LANES):
            sel_rows, gpm = golden_forward(t, lane_llrs[lane], block, depth)
            assert [pm[st][lane] for st in range(t.n_states)] == gpm, f"{code} lane {lane}"
            for s0 in (0, t.n_states - 1):
                assert simd_traceback(t, dw, lane, block, depth, s0) == \
                    golden_traceback(t, sel_rows, block, depth, s0), \
                    f"{code} lane {lane} s0={s0}"


def test_lane_group_splice_with_ragged_tail():
    t = build_trellis("k3")
    block, depth = 24, 18
    per_pb = (block + 2 * depth) * t.R
    rnd = random.Random(3)
    batch = LANES + 3  # one full group + ragged tail
    llr = [rnd.randint(-128, 127) for _ in range(batch * per_pb)]
    want = []
    for b in range(batch):
        sel_rows, _ = golden_forward(t, llr[b * per_pb:(b + 1) * per_pb], block, depth)
        want.extend(golden_traceback(t, sel_rows, block, depth, 0))
    got = []
    # full lane-group through the lockstep kernel
    lane_llrs = [llr[l * per_pb:(l + 1) * per_pb] for l in range(LANES)]
    dw, _ = simd_forward(t, lane_llrs, block, depth)
    for lane in range(LANES):
        got.extend(simd_traceback(t, dw, lane, block, depth, 0))
    # ragged tail through the scalar (golden-equivalent) fallback
    for p in range(LANES, batch):
        sel_rows, _ = golden_forward(t, llr[p * per_pb:(p + 1) * per_pb], block, depth)
        got.extend(golden_traceback(t, sel_rows, block, depth, 0))
    assert got == want


def test_u32_shift_keeps_tables_nonnegative_at_i8_extremes():
    # every stage value at the i8 minimum: R*128 shift must keep all
    # entries in [0, 2*R*128] (no u32 wrap anywhere in the fill)
    for r in (1, 2, 3):
        stage_vals = [[-128] * LANES for _ in range(r)]
        for row in fill_bm_lanes(stage_vals, r):
            for v in row:
                assert 0 <= v <= 2 * r * 128
    arr = np.array(fill_bm_lanes([[127] * LANES], 1), dtype=np.uint32)
    assert arr.max() <= 2 * 128
