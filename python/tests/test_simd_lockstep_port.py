"""Pure-python port of the Rust lane-interleaved SIMD ACS kernel
(rust/src/simd.rs) validated against the golden PBVD forward/traceback.

This is the executable specification of the lockstep algorithm at
**both metric widths**: the Gray-code interleaved branch-metric fill,
the `[state][lane]` SoA butterfly stage with lane-mask decisions —
u32 x 8 lanes with plain adds, u16 x 16 lanes with *saturating* adds —
and the per-lane traceback.  The u16 port models the exact semantics
of `u16::saturating_add` / `_mm256_adds_epu16` / `vqaddq_u16`, so the
spread-bound argument ("saturation never fires for admissible codes,
hence u16 decisions are bit-identical") is checked here from the
Python side too, including at the i8 extremes.

It is also the **backend-neutral spec of the stage schedule**
(rust/src/simd/backend.rs): `simd_forward` models the 256-bit AVX2
schedule (one full-width register per state row), and
`simd_forward_halves` the 128-bit NEON / portable lane-chunk schedule
(lo/hi half-vectors per state row, survivor masks spliced
`lo | hi << HALF`, running minimum tracked per half-register).  The
two must produce identical decision words and path metrics — the
claim that makes the Rust backend seam "one schedule, different
register widths".  The Rust property tests (rust/tests/simd_engine.rs,
rust/tests/overflow_guard.rs, rust/tests/backend_conformance.rs) pin
the real kernels against the real golden decoder; this module keeps
the algorithm itself regression-tested from the Python side (it needs
only numpy, so it runs in CI even without jax).
"""

import random

import numpy as np
import pytest

from compile.trellis import build_trellis

LANES_BY_WIDTH = {32: 8, 16: 16}
# lanes per 128-bit half-vector (rust/src/simd.rs Metric::HALF)
HALF_BY_WIDTH = {32: 4, 16: 8}
MAX_BY_WIDTH = {32: 0xFFFFFFFF, 16: 0xFFFF}
U32 = 0xFFFFFFFF


def spread_bound(r, k, q=8):
    """rust/src/simd.rs::metric_spread_bound — 2 * K * R * 2^q."""
    return 2 * k * r * (1 << q)


# ---------------------------------------------------------------------------
# Golden model (mirrors rust/src/viterbi.rs CpuPbvdDecoder).
# ---------------------------------------------------------------------------


def golden_forward(t, llr, block, depth):
    r, n, half = t.R, t.n_states, t.n_states // 2
    tt = block + 2 * depth
    assert len(llr) == tt * r
    pm = [0] * n
    sel_rows = []
    for s in range(tt):
        llr_s = llr[s * r:(s + 1) * r]
        bm = []
        for c in range(1 << r):
            acc = 0
            for ri in range(r):
                bit = (c >> (r - 1 - ri)) & 1
                acc += llr_s[ri] * (2 * bit - 1)
            bm.append(acc)
        new_pm = [0] * n
        sel = [0] * n
        for j in range(half):
            pe, po = pm[2 * j], pm[2 * j + 1]
            a = pe + bm[t.cw_top0[j]]
            b = po + bm[t.cw_top1[j]]
            sel[j] = 1 if b < a else 0
            new_pm[j] = min(a, b)
            a2 = pe + bm[t.cw_bot0[j]]
            b2 = po + bm[t.cw_bot1[j]]
            sel[j + half] = 1 if b2 < a2 else 0
            new_pm[j + half] = min(a2, b2)
        mn = min(new_pm)
        pm = [x - mn for x in new_pm]
        sel_rows.append(sel)
    return sel_rows, pm


def golden_traceback(t, sel_rows, block, depth, start_state):
    d, l = block, depth
    v = t.K - 1
    mask = (1 << (v - 1)) - 1
    state = start_state
    out = [0] * d
    for s in range(d + 2 * l - 1, l - 1, -1):
        if s <= d + l - 1:
            out[s - l] = (state >> (v - 1)) & 1
        bit = sel_rows[s][state]
        state = 2 * (state & mask) + bit
    return out


# ---------------------------------------------------------------------------
# Lane-interleaved kernel port (mirrors rust/src/simd.rs).
# ---------------------------------------------------------------------------


def gray_walk(r):
    """(codeword, llr_index, bit_now_set) per step — par.rs::gray_walk."""
    g = 0
    for i in range(1, 1 << (r - 1)):
        p = (i & -i).bit_length() - 1
        g ^= 1 << p
        yield g, r - 1 - p, (g >> p) & 1 == 1


def fill_bm_lanes(stage_vals, r, width=32, q=8):
    """stage_vals: [R][lanes] ints -> bm [2^R][lanes] at the metric
    width (uniform bm_offset(R, q) = R * 2^(q-1) shift)."""
    lanes = LANES_BY_WIDTH[width]
    wmax = MAX_BY_WIDTH[width]
    off = r * (1 << (q - 1))
    size = 1 << r
    mask = size - 1
    bm = [[0] * lanes for _ in range(size)]
    acc = [-sum(stage_vals[ri][lane] for ri in range(r)) for lane in range(lanes)]
    for lane in range(lanes):
        assert 0 <= off + acc[lane] <= wmax and 0 <= off - acc[lane] <= wmax, \
            "BM entry outside the metric width (inadmissible config)"
        bm[0][lane] = off + acc[lane]
        bm[mask][lane] = off - acc[lane]
    for g, ri, set_ in gray_walk(r):
        for lane in range(lanes):
            d = 2 * stage_vals[ri][lane]
            acc[lane] += d if set_ else -d
            bm[g][lane] = off + acc[lane]
            bm[mask ^ g][lane] = off - acc[lane]
    return bm


def simd_forward(t, lane_llrs, block, depth, width=32, q=8):
    """Returns (dw [T][N] lane masks, pm [N][lanes], saturated?).

    width=32 models the plain-add u32 kernel; width=16 the saturating
    u16 kernel (`saturating_add` / `_mm256_adds_epu16` semantics: adds
    clamp at 0xFFFF).  `saturated` reports whether any add actually
    clamped — the spread bound promises it never does for admissible
    codes, which test_u16_saturation_never_fires pins.
    """
    lanes = LANES_BY_WIDTH[width]
    wmax = MAX_BY_WIDTH[width]
    r, n, half = t.R, t.n_states, t.n_states // 2
    tt = block + 2 * depth
    pm = [[0] * lanes for _ in range(n)]
    dw = []
    saturated = False

    def add(x, y):
        nonlocal saturated
        s = x + y
        if s > wmax:
            saturated = True
            return wmax
        return s

    for s in range(tt):
        stage_vals = [[lane_llrs[lane][s * r + ri] for lane in range(lanes)]
                      for ri in range(r)]
        bm = fill_bm_lanes(stage_vals, r, width, q)
        new_pm = [[0] * lanes for _ in range(n)]
        dw_row = [0] * n
        minv = [wmax] * lanes
        for j in range(half):
            pe, po = pm[2 * j], pm[2 * j + 1]
            bt0, bt1 = bm[t.cw_top0[j]], bm[t.cw_top1[j]]
            bb0, bb1 = bm[t.cw_bot0[j]], bm[t.cw_bot1[j]]
            sel_top = sel_bot = 0
            for lane in range(lanes):
                a = add(pe[lane], bt0[lane])
                b = add(po[lane], bt1[lane])
                m = min(a, b)
                sel_top |= (1 if b < a else 0) << lane
                new_pm[j][lane] = m
                minv[lane] = min(minv[lane], m)
                a2 = add(pe[lane], bb0[lane])
                b2 = add(po[lane], bb1[lane])
                m2 = min(a2, b2)
                sel_bot |= (1 if b2 < a2 else 0) << lane
                new_pm[j + half][lane] = m2
                minv[lane] = min(minv[lane], m2)
            dw_row[j] = sel_top
            dw_row[j + half] = sel_bot
        for st in range(n):
            for lane in range(lanes):
                new_pm[st][lane] = new_pm[st][lane] - minv[lane]
        pm = new_pm
        dw.append(dw_row)
    return dw, pm, saturated


def simd_forward_halves(t, lane_llrs, block, depth, width=32, q=8):
    """The 128-bit half-vector schedule of the NEON and portable
    backends (rust/src/simd/backend.rs): each state row's lanes are
    processed as two HALF-lane chunks — one "register" at a time —
    with the per-chunk survivor masks spliced `lo | hi << HALF` and
    the running minimum kept per half-register lane.

    Returns (dw, pm, saturated) exactly like `simd_forward`; the two
    schedules must agree bit-for-bit (`test_half_vector_schedule_*`),
    which is the executable form of "the NEON schedule splices
    identically to the AVX2 schedule".
    """
    lanes = LANES_BY_WIDTH[width]
    h = HALF_BY_WIDTH[width]
    wmax = MAX_BY_WIDTH[width]
    r, n, half = t.R, t.n_states, t.n_states // 2
    tt = block + 2 * depth
    pm = [[0] * lanes for _ in range(n)]
    dw = []
    saturated = False

    def vqadd(a, b):
        # one vaddq/vqaddq over an h-lane chunk
        nonlocal saturated
        out = []
        for x, y in zip(a, b):
            s = x + y
            if s > wmax:
                saturated = True
                s = wmax
            out.append(s)
        return out

    def vmin(a, b):
        return [min(x, y) for x, y in zip(a, b)]

    def vlt_mask(b, a):
        # one vcltq + mask collapse over an h-lane chunk
        m = 0
        for i, (x, y) in enumerate(zip(b, a)):
            m |= (1 if x < y else 0) << i
        return m

    for s in range(tt):
        stage_vals = [[lane_llrs[lane][s * r + ri] for lane in range(lanes)]
                      for ri in range(r)]
        bm = fill_bm_lanes(stage_vals, r, width, q)
        new_pm = [[0] * lanes for _ in range(n)]
        dw_row = [0] * n
        minv = [wmax] * lanes
        for j in range(half):
            pe, po = pm[2 * j], pm[2 * j + 1]
            bt0, bt1 = bm[t.cw_top0[j]], bm[t.cw_top1[j]]
            bb0, bb1 = bm[t.cw_bot0[j]], bm[t.cw_bot1[j]]
            sel_top = sel_bot = 0
            for c in range(0, lanes, h):
                # lo / hi half-vectors of this state row
                a = vqadd(pe[c:c + h], bt0[c:c + h])
                b = vqadd(po[c:c + h], bt1[c:c + h])
                sel_top |= vlt_mask(b, a) << c
                new_pm[j][c:c + h] = vmin(a, b)
                minv[c:c + h] = vmin(minv[c:c + h], new_pm[j][c:c + h])
                a2 = vqadd(pe[c:c + h], bb0[c:c + h])
                b2 = vqadd(po[c:c + h], bb1[c:c + h])
                sel_bot |= vlt_mask(b2, a2) << c
                new_pm[j + half][c:c + h] = vmin(a2, b2)
                minv[c:c + h] = vmin(minv[c:c + h], new_pm[j + half][c:c + h])
            dw_row[j] = sel_top
            dw_row[j + half] = sel_bot
        for st in range(n):
            for lane in range(lanes):
                new_pm[st][lane] = new_pm[st][lane] - minv[lane]
        pm = new_pm
        dw.append(dw_row)
    return dw, pm, saturated


def simd_traceback(t, dw, lane, block, depth, start_state):
    d, l = block, depth
    v = t.K - 1
    mask = (1 << (v - 1)) - 1
    state = start_state
    out = [0] * d
    for s in range(d + 2 * l - 1, l - 1, -1):
        if s <= d + l - 1:
            out[s - l] = (state >> (v - 1)) & 1
        bit = (dw[s][state] >> lane) & 1
        state = 2 * (state & mask) + bit
    return out


# ---------------------------------------------------------------------------
# Depth-windowed ring-buffer survivor storage (mirrors the windowed
# decision buffers of rust/src/{viterbi,par,simd}.rs).
#
# Algorithm-1 traceback only ever reads stages depth..T-1 — the last
# D + L of the T = D + 2L forward stages.  A ring of C = D + L rows
# indexed `s % C` therefore retains exactly the stages traceback
# needs: the first `depth` stages are overwritten by stages
# D+L..T-1 (`s % C` is a bijection from any C consecutive stages onto
# the C ring rows), shrinking survivor memory from O(T·S) to
# O((D+L)·S) independent of how T relates to the ring size and
# whether depth >= block.
# ---------------------------------------------------------------------------


def ring_stages(block, depth):
    """Ring capacity C = D + L (rust: ForwardResult/kernel ring rows)."""
    return block + depth


def golden_forward_ring(t, llr, block, depth):
    """golden_forward with the survivor rows stored in a C-row ring
    (row `s % C`); returns (sel_ring [C][N], pm)."""
    sel_rows, pm = golden_forward(t, llr, block, depth)
    c = ring_stages(block, depth)
    ring = [[0] * t.n_states for _ in range(c)]
    for s, row in enumerate(sel_rows):  # ACS writes row s % C in stage order
        ring[s % c] = row
    return ring, pm


def golden_traceback_ring(t, sel_ring, block, depth, start_state):
    d, l = block, depth
    c = ring_stages(block, depth)
    v = t.K - 1
    mask = (1 << (v - 1)) - 1
    state = start_state
    out = [0] * d
    for s in range(d + 2 * l - 1, l - 1, -1):
        if s <= d + l - 1:
            out[s - l] = (state >> (v - 1)) & 1
        bit = sel_ring[s % c][state]
        state = 2 * (state & mask) + bit
    return out


def simd_forward_ring(t, lane_llrs, block, depth, width=32, q=8):
    """simd_forward with the lane-mask rows stored in a C-row ring;
    returns (dw_ring [C][N], pm, saturated)."""
    dw, pm, saturated = simd_forward(t, lane_llrs, block, depth, width, q)
    c = ring_stages(block, depth)
    ring = [[0] * t.n_states for _ in range(c)]
    for s, row in enumerate(dw):
        ring[s % c] = row
    return ring, pm, saturated


def simd_traceback_ring(t, dw_ring, lane, block, depth, start_state):
    d, l = block, depth
    c = ring_stages(block, depth)
    v = t.K - 1
    mask = (1 << (v - 1)) - 1
    state = start_state
    out = [0] * d
    for s in range(d + 2 * l - 1, l - 1, -1):
        if s <= d + l - 1:
            out[s - l] = (state >> (v - 1)) & 1
        bit = (dw_ring[s % c][state] >> lane) & 1
        state = 2 * (state & mask) + bit
    return out


# ---------------------------------------------------------------------------
# Tests.
# ---------------------------------------------------------------------------


def test_gray_walk_is_a_single_bit_gray_sequence():
    for r in (1, 2, 3, 4):
        seen = {0}
        g_prev = 0
        for g, ri, set_ in gray_walk(r):
            diff = g ^ g_prev
            assert diff.bit_count() == 1, "one bit flips per step"
            p = diff.bit_length() - 1
            assert ri == r - 1 - p
            assert set_ == bool((g >> p) & 1)
            assert g < (1 << (r - 1)), "stays in the lower half (MSB clear)"
            seen.add(g)
            g_prev = g
        assert seen == set(range(1 << (r - 1))), "visits every lower codeword"


@pytest.mark.parametrize("width", [32, 16])
def test_interleaved_fill_matches_direct_correlation(width):
    rnd = random.Random(7)
    lanes = LANES_BY_WIDTH[width]
    for r in (1, 2, 3):
        for _ in range(20):
            stage_vals = [[rnd.randint(-128, 127) for _ in range(lanes)]
                          for _ in range(r)]
            bm = fill_bm_lanes(stage_vals, r, width)
            off = r * 128
            for c in range(1 << r):
                for lane in range(lanes):
                    acc = sum(stage_vals[ri][lane] * (2 * ((c >> (r - 1 - ri)) & 1) - 1)
                              for ri in range(r))
                    assert bm[c][lane] == off + acc, \
                        f"w={width} r={r} c={c} lane={lane}"


@pytest.mark.parametrize("width", [32, 16])
@pytest.mark.parametrize("code", ["k3", "ccsds_k7"])
def test_lockstep_kernel_bit_identical_to_golden(code, width):
    t = build_trellis(code)
    lanes = LANES_BY_WIDTH[width]
    block, depth = 24, 6 * t.K
    tt = block + 2 * depth
    rnd = random.Random(0xB1F)
    for _ in range(2):
        lane_llrs = [[rnd.randint(-128, 127) for _ in range(tt * t.R)]
                     for _ in range(lanes)]
        dw, pm, saturated = simd_forward(t, lane_llrs, block, depth, width)
        assert not saturated, "admissible code must never saturate"
        for lane in range(lanes):
            sel_rows, gpm = golden_forward(t, lane_llrs[lane], block, depth)
            assert [pm[st][lane] for st in range(t.n_states)] == gpm, \
                f"{code} w={width} lane {lane}"
            for s0 in (0, t.n_states - 1):
                assert simd_traceback(t, dw, lane, block, depth, s0) == \
                    golden_traceback(t, sel_rows, block, depth, s0), \
                    f"{code} w={width} lane {lane} s0={s0}"


@pytest.mark.parametrize("width", [32, 16])
def test_lane_group_splice_with_ragged_tail(width):
    # Mirrors the Rust dispatch plan: full lane-groups through the
    # width's lockstep kernel, then (u16 mode) an 8..16-PB tail peels
    # one u32 lane-group, then the scalar (golden-equivalent) fallback.
    t = build_trellis("k3")
    lanes = LANES_BY_WIDTH[width]
    l32 = LANES_BY_WIDTH[32]
    block, depth = 24, 18
    per_pb = (block + 2 * depth) * t.R
    rnd = random.Random(3)
    # one full group + a tail big enough to trigger the u16 peel
    batch = lanes + l32 + 3
    llr = [rnd.randint(-128, 127) for _ in range(batch * per_pb)]
    want = []
    for b in range(batch):
        sel_rows, _ = golden_forward(t, llr[b * per_pb:(b + 1) * per_pb], block, depth)
        want.extend(golden_traceback(t, sel_rows, block, depth, 0))
    got = []
    # full lane-groups through the lockstep kernel
    full = batch // lanes
    for g in range(full):
        lane_llrs = [llr[(g * lanes + l) * per_pb:(g * lanes + l + 1) * per_pb]
                     for l in range(lanes)]
        dw, _, _ = simd_forward(t, lane_llrs, block, depth, width)
        for lane in range(lanes):
            got.extend(simd_traceback(t, dw, lane, block, depth, 0))
    off = full * lanes
    if width == 16 and batch - off >= l32:
        # the u16 tail peels one u32 lane-group
        lane_llrs = [llr[(off + l) * per_pb:(off + l + 1) * per_pb] for l in range(l32)]
        dw, _, _ = simd_forward(t, lane_llrs, block, depth, 32)
        for lane in range(l32):
            got.extend(simd_traceback(t, dw, lane, block, depth, 0))
        off += l32
    # remaining ragged tail through the scalar fallback
    for p in range(off, batch):
        sel_rows, _ = golden_forward(t, llr[p * per_pb:(p + 1) * per_pb], block, depth)
        got.extend(golden_traceback(t, sel_rows, block, depth, 0))
    assert got == want


@pytest.mark.parametrize("width", [32, 16])
@pytest.mark.parametrize("code", ["k3", "ccsds_k7"])
def test_half_vector_schedule_matches_full_width(code, width):
    # The backend-seam claim, executable: the 128-bit NEON/portable
    # half-vector schedule must splice to exactly the decision words
    # and path metrics of the 256-bit AVX2 full-width schedule — on
    # random frames AND at the adversarial extremes.
    t = build_trellis(code)
    lanes = LANES_BY_WIDTH[width]
    block, depth = 24, 6 * t.K
    tt = block + 2 * depth
    rnd = random.Random(0x41F ^ width)
    frames = []
    for _ in range(2):
        frames.append([[rnd.randint(-128, 127) for _ in range(tt * t.R)]
                       for _ in range(lanes)])
    extreme = [[-128] * (tt * t.R),
               [(-128 if i % 2 == 0 else 127) for i in range(tt * t.R)]]
    planted = [list(extreme[l % 2]) if l < 2 else
               [rnd.randint(-128, 127) for _ in range(tt * t.R)]
               for l in range(lanes)]
    frames.append(planted)
    for lane_llrs in frames:
        dw_full, pm_full, sat_full = simd_forward(t, lane_llrs, block, depth, width)
        dw_half, pm_half, sat_half = simd_forward_halves(t, lane_llrs, block, depth, width)
        assert dw_half == dw_full, f"{code} w={width}: decision words diverged"
        assert pm_half == pm_full, f"{code} w={width}: path metrics diverged"
        assert sat_half == sat_full
        # and both agree with the golden model per lane
        for lane in (0, lanes - 1):
            sel_rows, gpm = golden_forward(t, lane_llrs[lane], block, depth)
            assert [pm_half[st][lane] for st in range(t.n_states)] == gpm
            assert simd_traceback(t, dw_half, lane, block, depth, 0) == \
                golden_traceback(t, sel_rows, block, depth, 0)


@pytest.mark.parametrize("width", [32, 16])
def test_tie_break_uniform_across_schedules(width):
    # All-zero LLRs tie every butterfly at every stage; both schedules
    # must keep the even predecessor everywhere (mask 0 — the `b < a`
    # strict survivor condition all Rust backends share).
    t = build_trellis("k3")
    lanes = LANES_BY_WIDTH[width]
    block, depth = 8, 12
    zeros = [[0] * ((block + 2 * depth) * t.R) for _ in range(lanes)]
    for fwd in (simd_forward, simd_forward_halves):
        dw, _, saturated = fwd(t, zeros, block, depth, width)
        assert not saturated
        assert all(m == 0 for row in dw for m in row), \
            f"{fwd.__name__} w={width}: ties must keep the even predecessor"


def test_u32_shift_keeps_tables_nonnegative_at_i8_extremes():
    # every stage value at the i8 minimum: R*128 shift must keep all
    # entries in [0, 2*R*128] (no u32 wrap anywhere in the fill)
    for r in (1, 2, 3):
        stage_vals = [[-128] * LANES_BY_WIDTH[32] for _ in range(r)]
        for row in fill_bm_lanes(stage_vals, r):
            for v in row:
                assert 0 <= v <= 2 * r * 128
    arr = np.array(fill_bm_lanes([[127] * LANES_BY_WIDTH[32]], 1), dtype=np.uint32)
    assert arr.max() <= 2 * 128


@pytest.mark.parametrize("code", ["k3", "k5", "ccsds_k7", "r3_k7", "k9"])
def test_u16_saturation_never_fires_at_i8_extremes(code):
    # The spread-bound promise, pinned at the adversarial inputs: whole
    # frames of -128 and alternating ±extremes never clamp a u16 add,
    # and the u16 decisions equal the golden model's.
    t = build_trellis(code)
    assert spread_bound(t.R, t.K) <= 0xFFFF, f"{code} must be admissible"
    lanes = LANES_BY_WIDTH[16]
    block, depth = 24, 6 * t.K
    tt = block + 2 * depth
    patterns = [
        [-128] * (tt * t.R),
        [(-128 if i % 2 == 0 else 127) for i in range(tt * t.R)],
    ]
    for pat in patterns:
        lane_llrs = [list(pat) for _ in range(lanes)]
        dw, pm, saturated = simd_forward(t, lane_llrs, block, depth, width=16)
        assert not saturated, f"{code}: saturation fired inside the bound"
        sel_rows, gpm = golden_forward(t, pat, block, depth)
        assert [pm[st][0] for st in range(t.n_states)] == gpm
        assert simd_traceback(t, dw, 0, block, depth, 0) == \
            golden_traceback(t, sel_rows, block, depth, 0)
        assert max(max(row) for row in pm) < spread_bound(t.R, t.K), \
            f"{code}: normalized spread exceeded the bound"


@pytest.mark.parametrize("width", [32, 16])
@pytest.mark.parametrize("code,block,depth_mult", [
    ("k3", 24, 6),          # depth < block
    ("ccsds_k7", 24, 6),    # depth (42) > block (24)
    ("k3", 8, 9),           # depth (18) >> block (8)
])
def test_ring_window_bit_identical_to_full_buffer(code, block, depth_mult, width):
    # The tentpole claim, executable: a C = D + L ring retains exactly
    # the stages traceback walks, so decisions AND decoded bits are
    # bit-identical to the full T = D + 2L buffer — including when
    # depth >= block (the ring wraps more than once per forward).
    t = build_trellis(code)
    depth = depth_mult * t.K
    lanes = LANES_BY_WIDTH[width]
    tt = block + 2 * depth
    c = ring_stages(block, depth)
    assert c == block + depth and c < tt, "ring capacity is the depth window"
    rnd = random.Random(0x21C6 ^ width)
    lane_llrs = [[rnd.randint(-128, 127) for _ in range(tt * t.R)]
                 for _ in range(lanes)]
    dw, pm, _ = simd_forward(t, lane_llrs, block, depth, width)
    dw_ring, pm_ring, _ = simd_forward_ring(t, lane_llrs, block, depth, width)
    assert len(dw_ring) == c and len(dw) == tt
    assert pm_ring == pm
    # every retained stage of the window reads back identically...
    for s in range(depth, tt):
        assert dw_ring[s % c] == dw[s], f"stage {s} (slot {s % c})"
    # ...and repeated tracebacks from several start states stay valid
    # against one forward pass (the ring is read-only during traceback)
    for lane in (0, lanes - 1):
        for s0 in (0, 1, t.n_states - 1):
            assert simd_traceback_ring(t, dw_ring, lane, block, depth, s0) == \
                simd_traceback(t, dw, lane, block, depth, s0), \
                f"{code} w={width} lane={lane} s0={s0}"


@pytest.mark.parametrize("code,block,depth", [("k3", 24, 18), ("ccsds_k7", 8, 42)])
def test_golden_ring_matches_full_buffer(code, block, depth):
    # Same windowing claim for the scalar golden model's survivor rows
    # (rust/src/viterbi.rs ForwardResult), covering depth >= block.
    t = build_trellis(code)
    tt = block + 2 * depth
    rnd = random.Random(0x60D)
    llr = [rnd.randint(-128, 127) for _ in range(tt * t.R)]
    sel_rows, pm = golden_forward(t, llr, block, depth)
    sel_ring, pm_ring = golden_forward_ring(t, llr, block, depth)
    assert pm_ring == pm and len(sel_ring) == ring_stages(block, depth)
    for s0 in (0, 1, t.n_states - 1):
        assert golden_traceback_ring(t, sel_ring, block, depth, s0) == \
            golden_traceback(t, sel_rows, block, depth, s0)


def test_ring_slot_map_is_a_bijection_over_the_window():
    # s % C over the retained window depth..T-1 (C = D + L consecutive
    # stages) hits every ring row exactly once — the indexing fact the
    # overwrite correctness rests on, for ragged geometries where
    # D + 2L is not a multiple of C and for depth >= block.
    for block, depth in [(24, 18), (7, 5), (8, 18), (1, 1), (512, 42), (3, 11)]:
        c = ring_stages(block, depth)
        tt = block + 2 * depth
        slots = [s % c for s in range(depth, tt)]
        assert sorted(slots) == list(range(c)), f"D={block} L={depth}"
        # and the overwritten prefix is exactly stages 0..depth-1
        for s in range(depth):
            assert (s + c) < tt or depth == 0
            assert (s + c) % c == s % c


def test_spread_bound_rejects_synthetic_overflow_config():
    # rust/src/simd.rs::u16_metric_admissible's boundary: K=16, R=8 at
    # q=8 is 65536, one past u16::MAX; one quantizer bit less readmits.
    assert spread_bound(8, 16, 8) == 0xFFFF + 1
    assert spread_bound(8, 16, 7) <= 0xFFFF
    for code in ("k3", "k5", "ccsds_k7", "r3_k7", "k9"):
        t = build_trellis(code)
        assert spread_bound(t.R, t.K, 8) <= 0xFFFF
