"""Pallas kernels vs pure references (the core correctness signal).

Tiers compared:
  scalar numpy golden  ==  vectorized jnp ref  ==  Pallas kernels
plus end-to-end encode -> decode recovery.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.trellis import build_trellis
from compile.kernels import ref, acs
from compile.kernels import traceback as tbk


def make_llrs(trellis, B, T, noise, rng, amp=8):
    """Random encoded batch + int8 LLRs; returns (llrs [B,T,R] i8,
    payload bits [B, T])."""
    llrs = np.zeros((B, T, trellis.R), dtype=np.int8)
    bits = np.zeros((B, T), dtype=np.int64)
    for b in range(B):
        x = rng.integers(0, 2, T)
        cw = trellis.encode(x)
        y = (1 - 2 * cw) * amp + rng.normal(0, noise * amp, cw.shape)
        llrs[b] = np.clip(y, -127, 127).astype(np.int8)
        bits[b] = x
    return llrs, bits


CASES = [
    ("ccsds_k7", 64, 42),
    ("k3", 32, 15),
    ("k5", 64, 25),
    ("r3_k7", 64, 42),
]


@pytest.mark.parametrize("code,D,L", CASES)
def test_forward_kernel_vs_scalar_golden(code, D, L):
    t = build_trellis(code)
    rng = np.random.default_rng(7)
    T = D + 2 * L
    B = 8
    llrs, _ = make_llrs(t, B, T, noise=0.4, rng=rng)
    sp, pm = acs.forward_pallas(t, jnp.asarray(llrs), tile_b=8)
    sp, pm = np.asarray(sp), np.asarray(pm)
    for b in range(B):
        pm_np, sel = ref.viterbi_forward_np(t, llrs[b].astype(np.float64))
        assert np.array_equal(sp[b], ref.pack_sp_np(t, sel)), f"pb {b}"
        np.testing.assert_allclose(pm[b], pm_np, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("code,D,L", CASES)
def test_traceback_kernel_vs_scalar_golden(code, D, L):
    t = build_trellis(code)
    rng = np.random.default_rng(8)
    T = D + 2 * L
    B = 8
    llrs, _ = make_llrs(t, B, T, noise=0.5, rng=rng)
    sp, _ = acs.forward_pallas(t, jnp.asarray(llrs), tile_b=8)
    packed = np.asarray(tbk.traceback_pallas(t, sp, D=D, L=L, tile_b=8))
    got = ref.unpack_bits_np(packed, D)
    for b in range(B):
        _, sel = ref.viterbi_forward_np(t, llrs[b].astype(np.float64))
        want = ref.viterbi_traceback_np(t, sel, D, L)
        assert np.array_equal(got[b], want), f"pb {b}"


@pytest.mark.parametrize("code,D,L", CASES)
def test_kernels_vs_jnp_ref(code, D, L):
    t = build_trellis(code)
    rng = np.random.default_rng(9)
    B = 16
    llrs, _ = make_llrs(t, B, D + 2 * L, noise=0.6, rng=rng)
    x = jnp.asarray(llrs)
    sp_k, pm_k = acs.forward_pallas(t, x, tile_b=8)
    sp_r, pm_r = ref.forward_ref_jnp(t, x)
    assert np.array_equal(np.asarray(sp_k), np.asarray(sp_r))
    np.testing.assert_allclose(np.asarray(pm_k), np.asarray(pm_r), rtol=1e-6)
    tb_k = tbk.traceback_pallas(t, sp_k, D=D, L=L, tile_b=8)
    tb_r = ref.traceback_ref_jnp(t, sp_r, D, L)
    assert np.array_equal(np.asarray(tb_k), np.asarray(tb_r))


@pytest.mark.parametrize("code,D,L", CASES)
def test_end_to_end_noiseless_recovery(code, D, L):
    """With clean LLRs the PBVD must recover the payload exactly."""
    t = build_trellis(code)
    rng = np.random.default_rng(10)
    B = 8
    llrs, bits = make_llrs(t, B, D + 2 * L, noise=0.0, rng=rng)
    sp, _ = acs.forward_pallas(t, jnp.asarray(llrs), tile_b=8)
    packed = np.asarray(tbk.traceback_pallas(t, sp, D=D, L=L, tile_b=8))
    got = ref.unpack_bits_np(packed, D)
    want = bits[:, L:L + D].astype(np.int8)
    assert np.array_equal(got, want)


def test_end_to_end_low_noise_recovery():
    """Moderate noise at high effective SNR: zero errors expected."""
    t = build_trellis("ccsds_k7")
    rng = np.random.default_rng(11)
    D, L, B = 64, 42, 16
    llrs, bits = make_llrs(t, B, D + 2 * L, noise=0.25, rng=rng)
    sp, _ = acs.forward_pallas(t, jnp.asarray(llrs), tile_b=8)
    packed = np.asarray(tbk.traceback_pallas(t, sp, D=D, L=L, tile_b=8))
    got = ref.unpack_bits_np(packed, D)
    want = bits[:, L:L + D].astype(np.int8)
    assert np.array_equal(got, want)


def test_statebased_baseline_matches_grouped():
    """Ablation A1 invariant: state-based and group-based forward produce
    identical survivor paths (they differ only in BM computation count)."""
    t = build_trellis("ccsds_k7")
    rng = np.random.default_rng(12)
    D, L, B = 64, 42, 8
    llrs, _ = make_llrs(t, B, D + 2 * L, noise=0.7, rng=rng)
    sp_g, pm_g = acs.forward_pallas(t, jnp.asarray(llrs), tile_b=8)
    sp_s, pm_s = acs.forward_statebased_pallas(
        t, jnp.asarray(llrs, dtype=jnp.float32), tile_b=8
    )
    assert np.array_equal(np.asarray(sp_g), np.asarray(sp_s))
    np.testing.assert_allclose(np.asarray(pm_g), np.asarray(pm_s), rtol=1e-5)


def test_unpacked_traceback_matches_packed():
    """Ablation A2 invariant: U2 packing changes layout, not bits."""
    t = build_trellis("ccsds_k7")
    rng = np.random.default_rng(13)
    D, L, B = 64, 42, 8
    llrs, _ = make_llrs(t, B, D + 2 * L, noise=0.7, rng=rng)
    sp, _ = acs.forward_pallas(t, jnp.asarray(llrs), tile_b=8)
    packed = np.asarray(tbk.traceback_pallas(t, sp, D=D, L=L, tile_b=8))
    unpacked = np.asarray(
        tbk.traceback_unpacked_pallas(t, sp, D=D, L=L, tile_b=8)
    )
    assert np.array_equal(ref.unpack_bits_np(packed, D), unpacked.astype(np.int8))


def test_pbvd_agrees_with_block_viterbi_on_clean_stream():
    """PBVD mid-block decisions equal the classic block VA decisions when
    the channel is clean (truncation effects vanish)."""
    t = build_trellis("ccsds_k7")
    rng = np.random.default_rng(14)
    D, L = 64, 42
    T = D + 2 * L
    x = rng.integers(0, 2, T)
    cw = t.encode(x)
    llr = ((1 - 2 * cw) * 8).astype(np.float64)
    va = ref.block_viterbi_np(t, llr)
    pbvd = ref.pbvd_decode_np(t, llr, D, L)
    assert np.array_equal(pbvd, va[L:L + D])


def test_traceback_start_state_irrelevant():
    """Decoding-depth property (Sec. III-A): after L merge steps every
    start state yields the same decoded block."""
    t = build_trellis("ccsds_k7")
    rng = np.random.default_rng(15)
    D, L = 64, 42
    T = D + 2 * L
    x = rng.integers(0, 2, T)
    cw = t.encode(x)
    llr = (1 - 2 * cw) * 8 + rng.normal(0, 2.0, cw.shape)
    _, sel = ref.viterbi_forward_np(t, llr)
    base = ref.viterbi_traceback_np(t, sel, D, L, start_state=0)
    for s0 in (1, 17, 42, 63):
        assert np.array_equal(
            ref.viterbi_traceback_np(t, sel, D, L, start_state=s0), base
        )


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, codes, noise.
# ---------------------------------------------------------------------------

@given(
    code=st.sampled_from(["k3", "k5", "ccsds_k7"]),
    d32=st.integers(min_value=1, max_value=4),
    l=st.integers(min_value=8, max_value=48),
    tiles=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31),
    noise=st.floats(min_value=0.0, max_value=1.0),
)
@settings(max_examples=25, deadline=None)
def test_kernel_matches_ref_any_shape(code, d32, l, tiles, seed, noise):
    """Kernel == jnp ref for arbitrary (D, L, B) and noise levels."""
    t = build_trellis(code)
    D = 32 * d32
    B = 8 * tiles
    rng = np.random.default_rng(seed)
    llrs, _ = make_llrs(t, B, D + 2 * l, noise=noise, rng=rng)
    x = jnp.asarray(llrs)
    sp_k, pm_k = acs.forward_pallas(t, x, tile_b=8)
    sp_r, pm_r = ref.forward_ref_jnp(t, x)
    assert np.array_equal(np.asarray(sp_k), np.asarray(sp_r))
    tb_k = tbk.traceback_pallas(t, sp_k, D=D, L=l, tile_b=8)
    tb_r = ref.traceback_ref_jnp(t, sp_r, D, l)
    assert np.array_equal(np.asarray(tb_k), np.asarray(tb_r))


@given(seed=st.integers(min_value=0, max_value=2**31))
@settings(max_examples=10, deadline=None)
def test_extreme_llrs_no_overflow(seed):
    """Saturated int8 LLRs over a long block: PM normalization must keep
    metrics finite and decode must still match the golden model."""
    t = build_trellis("ccsds_k7")
    rng = np.random.default_rng(seed)
    D, L = 32, 20
    T = D + 2 * L
    llr = rng.choice(np.array([-128, 127], dtype=np.int8), size=(8, T, 2))
    sp, pm = acs.forward_pallas(t, jnp.asarray(llr), tile_b=8)
    assert np.isfinite(np.asarray(pm)).all()
    _, sel = ref.viterbi_forward_np(t, llr[0].astype(np.float64))
    assert np.array_equal(np.asarray(sp)[0], ref.pack_sp_np(t, sel))
