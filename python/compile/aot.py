"""AOT compile path: lower the L2 decode graphs to HLO **text** artifacts.

HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``:
  * ``<variant>_<code>_b<B>_d<D>_l<L>.hlo.txt`` — one per matrix entry
  * ``trellis_<code>.json`` — trellis tables for Rust cross-validation
  * ``manifest.json`` — machine-readable index the Rust runtime loads

Usage:  cd python && python -m compile.aot [--out ../artifacts]
        [--quick]  (test-size artifacts only, used by pytest)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model
from .trellis import CODES, build_trellis, export_json, table2


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe path).

    ``print_large_constants=True`` is REQUIRED: the default printer
    elides big constant payloads as ``{...}``, and the xla_extension
    0.5.1 text parser silently substitutes placeholder (iota-patterned)
    data for elided literals — the decoder's trellis tables would be
    quietly replaced by garbage.  (Bisected in examples/dbg_*.rs; see
    DESIGN.md §AOT-gotchas.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_variant(cfg: model.DecodeConfig, variant: str) -> str:
    fn, _ = model.VARIANTS[variant](cfg)
    lowered = jax.jit(fn).lower(*model.input_spec(cfg, variant))
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# The artifact matrix.
# ---------------------------------------------------------------------------

def default_matrix(quick: bool):
    """[(cfg, [variants])] to build.

    Paper parameters: D = 512, L = 42 for the (2,1,7) CCSDS code.  The
    batch ladder stands in for the paper's N_t sweep (Table III) at
    CPU-tractable sizes.  ``quick`` builds only the small test shapes.
    """
    mk = model.DecodeConfig
    two_kernel = ["forward", "traceback", "fused", "orig"]
    matrix = [
        # Small shapes: pytest + cargo integration tests.
        (mk("ccsds_k7", batch=32, block=64, depth=42), two_kernel),
        (mk("k3", batch=16, block=32, depth=15, tile_b=8), two_kernel),
    ]
    if not quick:
        matrix += [
            # Paper shape, batch ladder for Table III.
            (mk("ccsds_k7", batch=64, block=512, depth=42), two_kernel),
            (mk("ccsds_k7", batch=128, block=512, depth=42), two_kernel),
            (mk("ccsds_k7", batch=256, block=512, depth=42), two_kernel),
            # Fig. 4: BER vs L sweep (D = 512 fixed, L varies).
            (mk("ccsds_k7", batch=32, block=512, depth=7), ["fused"]),
            (mk("ccsds_k7", batch=32, block=512, depth=14), ["fused"]),
            (mk("ccsds_k7", batch=32, block=512, depth=21), ["fused"]),
            (mk("ccsds_k7", batch=32, block=512, depth=28), ["fused"]),
            (mk("ccsds_k7", batch=32, block=512, depth=42), ["fused"]),
            (mk("ccsds_k7", batch=32, block=512, depth=63), ["fused"]),
            # Generality: other standards' codes (Sec. I claim).
            (mk("k5", batch=32, block=64, depth=25), ["forward", "traceback", "fused"]),
            (mk("k9", batch=16, block=64, depth=45, tile_b=8), ["forward", "traceback", "fused"]),
            (mk("r3_k7", batch=32, block=64, depth=42), ["forward", "traceback", "fused"]),
        ]
    return matrix


def build_all(out_dir: str, quick: bool = False, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "generated_unix": int(time.time()), "entries": [],
                "codes": {}}

    for code in CODES:
        t = build_trellis(code)
        path = os.path.join(out_dir, f"trellis_{code}.json")
        export_json(t, path)
        manifest["codes"][code] = {
            "file": os.path.basename(path),
            "K": t.K, "R": t.R,
            "polys_octal": [format(p, "o") for p in t.polys],
            "n_states": t.n_states, "n_groups": t.n_groups,
            "n_sp_words": t.n_sp_words,
            "table2": table2(t),
        }

    for cfg, variants in default_matrix(quick):
        t = build_trellis(cfg.code)
        for variant in variants:
            name = cfg.name(variant)
            fname = f"{name}.hlo.txt"
            fpath = os.path.join(out_dir, fname)
            if os.path.exists(fpath) and not force:
                text = open(fpath).read()
                print(f"[aot] kept    {fname} ({len(text)} chars)")
            else:
                t0 = time.time()
                text = lower_variant(cfg, variant)
                with open(fpath, "w") as f:
                    f.write(text)
                print(f"[aot] lowered {fname} ({len(text)} chars, "
                      f"{time.time()-t0:.1f}s)")
            ins = [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in model.input_spec(cfg, variant)
            ]
            outs = [
                {"shape": list(shape), "dtype": dt}
                for shape, dt in model.output_spec(cfg, variant)
            ]
            manifest["entries"].append({
                "name": name,
                "file": fname,
                "variant": variant,
                "code": cfg.code,
                "batch": cfg.batch,
                "block": cfg.block,
                "depth": cfg.depth,
                "total": cfg.total,
                "tile_b": cfg.tile_b,
                "inputs": ins,
                "outputs": outs,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mpath}: {len(manifest['entries'])} artifacts")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="build only the small test artifacts")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file exists")
    args = ap.parse_args(argv)
    build_all(args.out, quick=args.quick, force=args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
