"""L2 — JAX decode graphs composed from the Pallas kernels.

Each public function is a jit-lowerable computation over one batch of
``B`` parallel blocks.  ``aot.py`` lowers these to HLO text artifacts
that the Rust runtime (rust/src/runtime) loads and executes; Python
never runs on the decode path.

Variants (the Table III experiment matrix):

  * ``forward_fn`` / ``traceback_fn`` — the optimized two-kernel decoder
    (paper K1 + K2): i8 quantized input, group-based ACS, bit-packed
    survivor paths, bit-packed decoded output.  The Rust coordinator
    chains them on-device (``execute_b``).
  * ``decode_fused_fn`` — both phases in one executable (ablation A3).
  * ``decode_orig_fn`` — the paper's "original decoder" baseline:
    ONE kernel, f32 soft input (no quantization packing), state-based
    BM computation (no group sharing), one i32 per decoded bit.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .trellis import Trellis, build_trellis
from .kernels import acs, traceback as tbk


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    """Static shape/parameter bundle for one artifact."""

    code: str          # key into trellis.CODES
    batch: int         # B = number of PBs decoded per executable call
    block: int         # D = decoded payload bits per PB
    depth: int         # L = traceback/truncation depth (M = L)
    tile_b: int = 8    # Pallas batch tile

    @property
    def total(self) -> int:  # T = D + 2L stages per PB
        return self.block + 2 * self.depth

    def name(self, variant: str) -> str:
        return (
            f"{variant}_{self.code}_b{self.batch}_d{self.block}_l{self.depth}"
        )


def make_forward_fn(cfg: DecodeConfig) -> Tuple[Callable, Trellis]:
    """K1: llr i8 [B, T, R] -> (sp u32 [B, T, W], pm f32 [B, N])."""
    trellis = build_trellis(cfg.code)

    def forward_fn(llr_i8):
        return acs.forward_pallas(trellis, llr_i8, tile_b=cfg.tile_b)

    return forward_fn, trellis


def make_traceback_fn(cfg: DecodeConfig) -> Tuple[Callable, Trellis]:
    """K2: sp u32 [B, T, W] -> bits u32 [B, D/32]."""
    trellis = build_trellis(cfg.code)

    def traceback_fn(sp):
        return tbk.traceback_pallas(
            trellis, sp, D=cfg.block, L=cfg.depth, tile_b=cfg.tile_b
        )

    return traceback_fn, trellis


def make_decode_fused_fn(cfg: DecodeConfig) -> Tuple[Callable, Trellis]:
    """K1+K2 in one executable: llr i8 [B, T, R] -> bits u32 [B, D/32]."""
    trellis = build_trellis(cfg.code)

    def decode_fused_fn(llr_i8):
        sp, _pm = acs.forward_pallas(trellis, llr_i8, tile_b=cfg.tile_b)
        return tbk.traceback_pallas(
            trellis, sp, D=cfg.block, L=cfg.depth, tile_b=cfg.tile_b
        )

    return decode_fused_fn, trellis


def make_decode_orig_fn(cfg: DecodeConfig) -> Tuple[Callable, Trellis]:
    """Original-decoder baseline: llr f32 [B, T, R] -> bits i32 [B, D]."""
    trellis = build_trellis(cfg.code)

    def decode_orig_fn(llr_f32):
        sp, _pm = acs.forward_statebased_pallas(
            trellis, llr_f32, tile_b=cfg.tile_b
        )
        return tbk.traceback_unpacked_pallas(
            trellis, sp, D=cfg.block, L=cfg.depth, tile_b=cfg.tile_b
        )

    return decode_orig_fn, trellis


#: variant name -> (factory, input dtype builder)
def input_spec(cfg: DecodeConfig, variant: str):
    """ShapeDtypeStruct(s) of the variant's input."""
    trellis = build_trellis(cfg.code)
    T, R, B = cfg.total, trellis.R, cfg.batch
    W = trellis.n_sp_words
    if variant == "forward":
        return (jax.ShapeDtypeStruct((B, T, R), jnp.int8),)
    if variant == "traceback":
        return (jax.ShapeDtypeStruct((B, T, W), jnp.uint32),)
    if variant == "fused":
        return (jax.ShapeDtypeStruct((B, T, R), jnp.int8),)
    if variant == "orig":
        return (jax.ShapeDtypeStruct((B, T, R), jnp.float32),)
    raise ValueError(f"unknown variant {variant!r}")


def output_spec(cfg: DecodeConfig, variant: str):
    """[(shape, dtype-name)] of the variant's outputs (manifest entry)."""
    trellis = build_trellis(cfg.code)
    T, B = cfg.total, cfg.batch
    W = trellis.n_sp_words
    N = trellis.n_states
    D = cfg.block
    if variant == "forward":
        return [((B, T, W), "u32"), ((B, N), "f32")]
    if variant == "traceback":
        return [((B, D // 32), "u32")]
    if variant == "fused":
        return [((B, D // 32), "u32")]
    if variant == "orig":
        return [((B, D), "i32")]
    raise ValueError(f"unknown variant {variant!r}")


VARIANTS: Dict[str, Callable[[DecodeConfig], Tuple[Callable, Trellis]]] = {
    "forward": make_forward_fn,
    "traceback": make_traceback_fn,
    "fused": make_decode_fused_fn,
    "orig": make_decode_orig_fn,
}
