"""Trellis, butterfly and group-classification tables for (R,1,K) codes.

This is the build-time twin of ``rust/src/trellis``: both implement the
paper's group-based classification (Sec. III-B, eqs. (2)-(6)) and are
cross-checked against each other through the JSON export
(``artifacts/trellis_<code>.json``).

Conventions (matching the paper):
  * ``K`` constraint length, ``R`` outputs per input bit, ``v = K - 1``
    memory bits, ``N = 2**v`` states.
  * State ``d = (D_{v-1} ... D_1 D_0)_2`` with ``D_{v-1}`` the *newest*
    bit.  Input ``x`` shifts in at the MSB:
    ``next(d, x) = (x << (v-1)) | (d >> 1)``.
  * Generator ``g^{(r)} = [g_{K-1} ... g_0]`` written MSB-first; the MSB
    tap multiplies the input bit ``x`` (eq. (2)).
  * Butterfly ``j`` (``j = 0 .. N/2-1``): source states ``2j, 2j+1``,
    target states ``j`` (input 0) and ``j + N/2`` (input 1).
  * Codewords are packed into integers MSB-first: output of filter 1 is
    the most significant bit (so the paper's ``alpha = 01`` for R = 2 is
    the integer 1).
  * Group ids are assigned in order of first occurrence over ascending
    butterfly index; this reproduces Table II's numbering exactly.
  * Survivor-path words: the k-th butterfly of group ``w`` stores the
    select bit of target state ``j`` at logical bit ``2k`` and of target
    ``j + N/2`` at logical bit ``2k + 1`` inside group ``w``'s word.
    When a group needs more than 32 bits the word is split; see
    ``sp_word`` / ``sp_bit``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Code registry (octal generator notation, MSB-first as in the paper).
# ---------------------------------------------------------------------------

#: name -> (K, [generator polynomials as integers, MSB = input tap])
CODES: Dict[str, Tuple[int, List[int]]] = {
    # CCSDS / Voyager (2,1,7): g1 = 1111001b = 0o171, g2 = 1011011b = 0o133.
    # This is the paper's primary code (Sec. V, Table II).
    "ccsds_k7": (7, [0o171, 0o133]),
    # (2,1,5) e.g. GSM-ish toy code [23, 35]_8.
    "k5": (5, [0o23, 0o35]),
    # (2,1,9) long-constraint code [561, 753]_8 (IS-95 style).
    "k9": (9, [0o561, 0o753]),
    # (3,1,7) rate-1/3 [133, 145, 175]_8 (LTE-ish).
    "r3_k7": (7, [0o133, 0o145, 0o175]),
    # Tiny (2,1,3) [7, 5]_8 — the classic textbook code, used in tests.
    "k3": (3, [0o7, 0o5]),
}


def parity(x: int) -> int:
    """Parity of the set bits of ``x`` (GF(2) sum)."""
    return bin(x).count("1") & 1


@dataclasses.dataclass
class Trellis:
    """All decode-time tables for one (R,1,K) code.

    Every array is a plain ``np.ndarray`` so kernels can capture them as
    compile-time constants.
    """

    name: str
    K: int
    polys: List[int]          # MSB-first generator taps
    R: int                    # outputs per input bit
    v: int                    # memory bits
    n_states: int             # N = 2**v
    n_groups: int             # N_c <= 2**R
    # --- per (state, input) ------------------------------------------------
    next_state: np.ndarray    # [N, 2] int32
    output: np.ndarray        # [N, 2] int32 codeword in 0..2**R-1
    # --- butterflies --------------------------------------------------------
    bfly_alpha: np.ndarray    # [N/2] int32 codeword alpha of butterfly j
    bfly_group: np.ndarray    # [N/2] int32 group id
    group_alpha: np.ndarray   # [N_c] int32 alpha per group
    group_bflys: List[List[int]]  # per group: butterfly indices ascending
    # group labels alpha/beta/gamma/theta as codeword ints, [N_c, 4]
    group_labels: np.ndarray
    # per-butterfly BM labels for the vectorized ACS:
    cw_top0: np.ndarray       # [N/2] label of (2j,   x=0) = alpha
    cw_top1: np.ndarray       # [N/2] label of (2j+1, x=0) = gamma
    cw_bot0: np.ndarray       # [N/2] label of (2j,   x=1) = beta
    cw_bot1: np.ndarray       # [N/2] label of (2j+1, x=1) = theta
    # --- survivor-path packing ---------------------------------------------
    words_per_group: int      # ceil((N/N_c) / 32)
    n_sp_words: int           # N_c * words_per_group
    sp_word: np.ndarray       # [N] int32 word index of target state's bit
    sp_bit: np.ndarray        # [N] int32 bit index (0..31)
    # word_states[w, b] = target state whose select bit is bit b of word w
    # (padded with -1 when the word is not full)
    word_states: np.ndarray   # [n_sp_words, 32] int32
    # --- branch metric signs -----------------------------------------------
    # cw_signs[r, c] = +1 if bit r of codeword c is 1 else -1  (min-ACS
    # correlation form: BM[c] = sum_r llr_r * (2 c_r - 1))
    cw_signs: np.ndarray      # [R, 2**R] float32

    # -- helpers -------------------------------------------------------------

    def encode(self, bits: np.ndarray, state: int = 0) -> np.ndarray:
        """Encode ``bits`` (ints 0/1) from ``state``; returns [len, R] bits."""
        out = np.zeros((len(bits), self.R), dtype=np.int64)
        for i, x in enumerate(np.asarray(bits, dtype=np.int64)):
            cw = self.output[state, x]
            for r in range(self.R):
                out[i, r] = (cw >> (self.R - 1 - r)) & 1
            state = self.next_state[state, x]
        return out

    def codeword_bits(self, cw: int) -> List[int]:
        return [(cw >> (self.R - 1 - r)) & 1 for r in range(self.R)]

    def to_json_dict(self) -> dict:
        return {
            "name": self.name,
            "K": self.K,
            "R": self.R,
            "polys_octal": [format(p, "o") for p in self.polys],
            "n_states": self.n_states,
            "n_groups": self.n_groups,
            "words_per_group": self.words_per_group,
            "n_sp_words": self.n_sp_words,
            "next_state": self.next_state.tolist(),
            "output": self.output.tolist(),
            "bfly_group": self.bfly_group.tolist(),
            "group_alpha": self.group_alpha.tolist(),
            "group_labels": self.group_labels.tolist(),
            "group_bflys": self.group_bflys,
            "sp_word": self.sp_word.tolist(),
            "sp_bit": self.sp_bit.tolist(),
        }


def encoder_output(polys: List[int], K: int, state: int, x: int) -> int:
    """Eq. (2): codeword (as int, filter 1 = MSB) for input ``x`` at ``state``."""
    reg = (x << (K - 1)) | state  # x occupies the g_{K-1} tap position
    cw = 0
    for p in polys:
        cw = (cw << 1) | parity(reg & p)
    return cw


def build_trellis(name: str) -> Trellis:
    """Construct every table for the named code (see ``CODES``)."""
    K, polys = CODES[name]
    R = len(polys)
    v = K - 1
    N = 1 << v
    half = N // 2

    next_state = np.zeros((N, 2), dtype=np.int32)
    output = np.zeros((N, 2), dtype=np.int32)
    for d in range(N):
        for x in (0, 1):
            next_state[d, x] = (x << (v - 1)) | (d >> 1)
            output[d, x] = encoder_output(polys, K, d, x)

    # Butterfly classification by alpha = output(2j, x=0)  (eqs. (3)-(6)).
    bfly_alpha = np.array([output[2 * j, 0] for j in range(half)], dtype=np.int32)
    group_of_alpha: Dict[int, int] = {}
    bfly_group = np.zeros(half, dtype=np.int32)
    group_bflys: List[List[int]] = []
    for j in range(half):
        a = int(bfly_alpha[j])
        if a not in group_of_alpha:
            group_of_alpha[a] = len(group_of_alpha)
            group_bflys.append([])
        w = group_of_alpha[a]
        bfly_group[j] = w
        group_bflys[w].append(j)
    n_groups = len(group_of_alpha)
    group_alpha = np.zeros(n_groups, dtype=np.int32)
    for a, w in group_of_alpha.items():
        group_alpha[w] = a

    # alpha/beta/gamma/theta per group.  beta = alpha ^ msb_taps,
    # gamma = alpha ^ lsb_taps, theta = alpha ^ msb ^ lsb  (eqs. (4)-(6)).
    msb_taps = 0
    lsb_taps = 0
    for p in polys:
        msb_taps = (msb_taps << 1) | ((p >> (K - 1)) & 1)
        lsb_taps = (lsb_taps << 1) | (p & 1)
    group_labels = np.zeros((n_groups, 4), dtype=np.int32)
    for w in range(n_groups):
        a = int(group_alpha[w])
        group_labels[w] = [a, a ^ msb_taps, a ^ lsb_taps, a ^ msb_taps ^ lsb_taps]

    # Per-butterfly ACS labels.
    cw_top0 = np.array([output[2 * j, 0] for j in range(half)], dtype=np.int32)
    cw_top1 = np.array([output[2 * j + 1, 0] for j in range(half)], dtype=np.int32)
    cw_bot0 = np.array([output[2 * j, 1] for j in range(half)], dtype=np.int32)
    cw_bot1 = np.array([output[2 * j + 1, 1] for j in range(half)], dtype=np.int32)

    # Consistency with the derivation: top0 must equal the group alpha, etc.
    for j in range(half):
        w = int(bfly_group[j])
        assert cw_top0[j] == group_labels[w][0]
        assert cw_bot0[j] == group_labels[w][1]
        assert cw_top1[j] == group_labels[w][2]
        assert cw_bot1[j] == group_labels[w][3]

    # Survivor-path packing tables.
    bits_per_group = 2 * max(len(b) for b in group_bflys)
    words_per_group = (bits_per_group + 31) // 32
    n_sp_words = n_groups * words_per_group
    sp_word = np.full(N, -1, dtype=np.int32)
    sp_bit = np.full(N, -1, dtype=np.int32)
    word_states = np.full((n_sp_words, 32), -1, dtype=np.int32)
    for w in range(n_groups):
        for k, j in enumerate(group_bflys[w]):
            for xhat, tgt in ((0, j), (1, j + half)):
                logical = 2 * k + xhat
                word = w * words_per_group + logical // 32
                bit = logical % 32
                sp_word[tgt] = word
                sp_bit[tgt] = bit
                word_states[word, bit] = tgt
    assert (sp_word >= 0).all() and (sp_bit >= 0).all()

    # BM sign matrix.
    n_cw = 1 << R
    cw_signs = np.zeros((R, n_cw), dtype=np.float32)
    for c in range(n_cw):
        for r in range(R):
            bit = (c >> (R - 1 - r)) & 1
            cw_signs[r, c] = 1.0 if bit else -1.0

    return Trellis(
        name=name, K=K, polys=polys, R=R, v=v, n_states=N,
        n_groups=n_groups, next_state=next_state, output=output,
        bfly_alpha=bfly_alpha, bfly_group=bfly_group,
        group_alpha=group_alpha, group_bflys=group_bflys,
        group_labels=group_labels,
        cw_top0=cw_top0, cw_top1=cw_top1, cw_bot0=cw_bot0, cw_bot1=cw_bot1,
        words_per_group=words_per_group, n_sp_words=n_sp_words,
        sp_word=sp_word, sp_bit=sp_bit, word_states=word_states,
        cw_signs=cw_signs,
    )


def table2(trellis: Trellis) -> List[dict]:
    """Reproduce the paper's Table II rows for any code.

    Each row: group id, alpha/beta/gamma/theta as bit strings, and the
    sorted list of *source* states (both states of every butterfly in
    the group) — the paper's "Index of states" column.
    """
    rows = []
    for w in range(trellis.n_groups):
        states = sorted(
            s for j in trellis.group_bflys[w] for s in (2 * j, 2 * j + 1)
        )
        labels = [
            format(int(c), f"0{trellis.R}b") for c in trellis.group_labels[w]
        ]
        rows.append({
            "group": w,
            "alpha": labels[0], "beta": labels[1],
            "gamma": labels[2], "theta": labels[3],
            "states": states,
        })
    return rows


def export_json(trellis: Trellis, path: str) -> None:
    with open(path, "w") as f:
        json.dump(trellis.to_json_dict(), f, indent=1)


if __name__ == "__main__":
    t = build_trellis("ccsds_k7")
    for row in table2(t):
        print(row)
