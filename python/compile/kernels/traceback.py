"""K2 — traceback + decode Pallas kernel (paper Algorithm 1, Kernel 2).

The paper runs one CUDA thread per parallel block (traceback is serial
per PB); here every vector lane of a batch tile walks its own survivor
chain, so the kernel is a sequential scan over stages with per-lane
gathers — the same parallelism split expressed for a vector unit.

Two phases (Fig. 1):
  * merge:   stages T-1 .. D+L — walk from an arbitrary state (0); after
    L steps all survivor paths have merged with high probability.
  * decode:  stages D+L-1 .. L — emit the MSB of the current state for
    each stage; bit for stage s lands at position s-L of the D-block.

Decoded bits are emitted bit-packed (32 bits per u32 word) — the
paper's U2 = 1/8 D2H packing.  ``traceback_unpacked_pallas`` is the
Table III "original decoder" variant (one i32 per bit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..trellis import Trellis


def traceback_tables(trellis: Trellis):
    """(tb_word [N] i32, tb_bit [N] u32) lookup tables (Algorithm 1 l.18)."""
    return trellis.sp_word.astype(np.int32), trellis.sp_bit.astype(np.uint32)


def _walk(sp_rev, tb_word, tb_bit, tile_b, v, D, L):
    """Shared merge+decode walk; returns bits [B, D] uint32.

    The per-state LUT reads of Algorithm 1 line 18 (``tb_word[state]``)
    are expressed as one-hot contractions (compare against an iota,
    multiply, reduce) rather than gathers: on a real TPU the VPU has no
    fast dynamic gather, while compare+select+reduce vectorizes across
    lanes — this is the canonical Mosaic idiom for small-table lookups.
    Gathers from *data* (``take_along_axis`` on sp) keep their natural
    form.  (Historical note: this also sidestepped a debugging rabbit
    hole where elided ``{...}`` constants in the HLO text were silently
    placeholder-filled by the xla_extension 0.5.1 parser — fixed for
    real by ``print_large_constants=True`` in aot.py; bisection
    recorded in DESIGN.md §AOT-gotchas.)
    """
    n_states = tb_word.shape[0]
    n_words = sp_rev.shape[2]
    mask = (1 << (v - 1)) - 1
    # §Perf: fuse the two LUTs into ONE contraction (packed = w*64 + b,
    # values < N*64 so the int32 one-hot reduce is exact), and replace
    # the per-lane word gather with a one-hot select over the (small)
    # W axis — no gathers anywhere in the walk.
    packed_lut = tb_word * 64 + tb_bit.astype(jnp.int32)       # [N]

    def step(state, sp_s):
        iota = jax.lax.broadcasted_iota(jnp.int32, (tile_b, n_states), 1)
        onehot = (state[:, None] == iota).astype(jnp.int32)    # [B, N]
        packed = (onehot * packed_lut[None, :]).sum(axis=1)    # [B]
        w = packed >> 6
        b = (packed & 63).astype(jnp.uint32)
        wiota = jax.lax.broadcasted_iota(jnp.int32, (tile_b, n_words), 1)
        wsel = (w[:, None] == wiota).astype(jnp.uint32)        # [B, W]
        word = (sp_s * wsel).sum(axis=1)                       # [B]
        bit = ((word >> b) & 1).astype(jnp.int32)
        out = (state >> (v - 1)) & 1                           # MSB = input bit
        nxt = 2 * (state & mask) + bit
        return nxt, out

    state0 = jnp.zeros((tile_b,), jnp.int32)
    state, _ = jax.lax.scan(step, state0, sp_rev[:L])          # merge
    _, bits_rev = jax.lax.scan(step, state, sp_rev[L:L + D])   # decode
    return jnp.swapaxes(bits_rev[::-1], 0, 1).astype(jnp.uint32)  # [B, D]


def _traceback_kernel_body(
    sp_ref, word_ref, bit_ref, out_ref, *, v: int, D: int, L: int
):
    tile_b, T, W = sp_ref.shape
    assert T == D + 2 * L
    sp_rev = jnp.swapaxes(sp_ref[...], 0, 1)[::-1]            # [T, B, W]
    bits = _walk(sp_rev, word_ref[...], bit_ref[...], tile_b, v, D, L)
    g = bits.reshape(tile_b, D // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, g.shape, 2)
    out_ref[...] = (g << shifts).sum(axis=2, dtype=jnp.uint32)


def _table_spec(shape):
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def traceback_pallas(
    trellis: Trellis, sp: jnp.ndarray, *, D: int, L: int, tile_b: int = 8
):
    """Batched traceback: sp [B, T, W] uint32 -> bits [B, D//32] uint32."""
    B, T, W = sp.shape
    assert B % tile_b == 0 and D % 32 == 0
    tb_word, tb_bit = traceback_tables(trellis)
    kernel = functools.partial(
        _traceback_kernel_body, v=trellis.v, D=D, L=L
    )
    return pl.pallas_call(
        kernel,
        grid=(B // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, T, W), lambda i: (i, 0, 0)),
            _table_spec(tb_word.shape),
            _table_spec(tb_bit.shape),
        ],
        out_specs=[pl.BlockSpec((tile_b, D // 32), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, D // 32), jnp.uint32)],
        interpret=True,
    )(sp, tb_word, tb_bit)[0]


def _traceback_unpacked_body(
    sp_ref, word_ref, bit_ref, out_ref, *, v: int, D: int, L: int
):
    """Baseline variant: one i32 per decoded bit (no U2 packing)."""
    tile_b, T, W = sp_ref.shape
    sp_rev = jnp.swapaxes(sp_ref[...], 0, 1)[::-1]
    bits = _walk(sp_rev, word_ref[...], bit_ref[...], tile_b, v, D, L)
    out_ref[...] = bits.astype(jnp.int32)


def traceback_unpacked_pallas(
    trellis: Trellis, sp: jnp.ndarray, *, D: int, L: int, tile_b: int = 8
):
    """Baseline traceback: sp [B, T, W] -> bits [B, D] int32 (one per bit)."""
    B, T, W = sp.shape
    assert B % tile_b == 0
    tb_word, tb_bit = traceback_tables(trellis)
    kernel = functools.partial(
        _traceback_unpacked_body, v=trellis.v, D=D, L=L
    )
    return pl.pallas_call(
        kernel,
        grid=(B // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, T, W), lambda i: (i, 0, 0)),
            _table_spec(tb_word.shape),
            _table_spec(tb_bit.shape),
        ],
        out_specs=[pl.BlockSpec((tile_b, D), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, D), jnp.int32)],
        interpret=True,
    )(sp, tb_word, tb_bit)[0]
