"""Pure-numpy / pure-jnp correctness oracles for the PBVD kernels.

Three tiers, from slowest/most-obviously-correct to fastest:

  * ``viterbi_forward_np`` / ``viterbi_traceback_np`` — textbook scalar
    loops over one parallel block.  The golden model.
  * ``pbvd_decode_np`` — the full PBVD decode of one PB (forward with
    zero initial metrics, traceback from state 0, emit the mid D bits).
  * ``forward_ref_jnp`` / ``traceback_ref_jnp`` — vectorized jnp
    re-implementations with the *same* input/output contract as the
    Pallas kernels (including SP word packing), used by pytest for
    batched comparison and by hypothesis sweeps.

Branch metric convention (min-ACS correlation form):
    BM[c] = sum_r llr_r * (2 c_r - 1)
where llr_r is the received soft value for coded bit r and BPSK maps
bit 0 -> +1, bit 1 -> -1.  Minimizing this is equivalent to minimizing
Euclidean distance to the candidate codeword.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..trellis import Trellis


# ---------------------------------------------------------------------------
# Tier 1: textbook scalar implementation (one PB).
# ---------------------------------------------------------------------------

def branch_metrics_np(trellis: Trellis, llr_stage: np.ndarray) -> np.ndarray:
    """BM table [2**R] for one stage from llr [R]."""
    n_cw = 1 << trellis.R
    bm = np.zeros(n_cw, dtype=np.float64)
    for c in range(n_cw):
        for r, bit in enumerate(trellis.codeword_bits(c)):
            bm[c] += float(llr_stage[r]) * (2 * bit - 1)
    return bm


def viterbi_forward_np(trellis: Trellis, llr: np.ndarray):
    """Scalar forward ACS over llr [T, R] with zero initial metrics.

    Returns (pm_final [N] float64, sel [T, N] int8) where sel[s, t] is
    the survivor select bit of *target* state t at stage s (0 = even
    predecessor 2j, 1 = odd predecessor 2j+1).
    """
    N = trellis.n_states
    half = N // 2
    T = llr.shape[0]
    pm = np.zeros(N, dtype=np.float64)
    sel = np.zeros((T, N), dtype=np.int8)
    for s in range(T):
        bm = branch_metrics_np(trellis, llr[s])
        new_pm = np.zeros(N, dtype=np.float64)
        for j in range(half):
            pe, po = pm[2 * j], pm[2 * j + 1]
            # target j (input 0)
            a = pe + bm[trellis.cw_top0[j]]
            b = po + bm[trellis.cw_top1[j]]
            sel[s, j] = 1 if b < a else 0
            new_pm[j] = min(a, b)
            # target j + N/2 (input 1)
            a = pe + bm[trellis.cw_bot0[j]]
            b = po + bm[trellis.cw_bot1[j]]
            sel[s, j + half] = 1 if b < a else 0
            new_pm[j + half] = min(a, b)
        new_pm -= new_pm.min()  # same normalization as the kernel
        pm = new_pm
    return pm, sel


def pack_sp_np(trellis: Trellis, sel: np.ndarray) -> np.ndarray:
    """Pack sel [T, N] into SP words [T, n_sp_words] uint32 (Fig. 3 layout)."""
    T = sel.shape[0]
    sp = np.zeros((T, trellis.n_sp_words), dtype=np.uint32)
    for t in range(trellis.n_states):
        w, b = int(trellis.sp_word[t]), int(trellis.sp_bit[t])
        sp[:, w] |= (sel[:, t].astype(np.uint32)) << b
    return sp


def viterbi_traceback_np(
    trellis: Trellis, sel: np.ndarray, D: int, L: int, start_state: int = 0
) -> np.ndarray:
    """Scalar traceback (paper Algorithm 1, Kernel 2) over sel [T, N].

    T must equal D + 2L.  Walks from ``start_state`` at stage T-1 down
    to stage L, emitting the MSB of the current state for stages
    s <= D + L - 1.  Returns the D decoded bits in natural order.
    """
    T = sel.shape[0]
    assert T == D + 2 * L, (T, D, L)
    v = trellis.v
    state = start_state
    bits = np.zeros(D, dtype=np.int8)
    for s in range(T - 1, L - 1, -1):
        if s <= D + L - 1:
            bits[s - L] = (state >> (v - 1)) & 1
        sp_bit = int(sel[s, state])
        state = 2 * (state % (1 << (v - 1))) + sp_bit
    return bits


def pbvd_decode_np(
    trellis: Trellis, llr: np.ndarray, D: int, L: int, start_state: int = 0
) -> np.ndarray:
    """Full PBVD decode of one PB: llr [D+2L, R] -> D bits."""
    _, sel = viterbi_forward_np(trellis, llr)
    return viterbi_traceback_np(trellis, sel, D, L, start_state)


def block_viterbi_np(trellis: Trellis, llr: np.ndarray) -> np.ndarray:
    """Classic block VA (not PBVD): known start state 0, traceback from
    the argmin final state, decode every stage.  Used to sanity-check
    the PBVD against the textbook decoder on clean inputs."""
    N = trellis.n_states
    T = llr.shape[0]
    v = trellis.v
    pm = np.full(N, 1e18)
    pm[0] = 0.0
    sel = np.zeros((T, N), dtype=np.int8)
    for s in range(T):
        bm = branch_metrics_np(trellis, llr[s])
        new_pm = np.zeros(N)
        for j in range(N // 2):
            pe, po = pm[2 * j], pm[2 * j + 1]
            a, b = pe + bm[trellis.cw_top0[j]], po + bm[trellis.cw_top1[j]]
            sel[s, j] = 1 if b < a else 0
            new_pm[j] = min(a, b)
            a, b = pe + bm[trellis.cw_bot0[j]], po + bm[trellis.cw_bot1[j]]
            sel[s, j + N // 2] = 1 if b < a else 0
            new_pm[j + N // 2] = min(a, b)
        pm = new_pm
    state = int(np.argmin(pm))
    bits = np.zeros(T, dtype=np.int8)
    for s in range(T - 1, -1, -1):
        bits[s] = (state >> (v - 1)) & 1
        state = 2 * (state % (1 << (v - 1))) + int(sel[s, state])
    return bits


# ---------------------------------------------------------------------------
# Tier 3: vectorized jnp references with the kernel I/O contract.
# ---------------------------------------------------------------------------

def forward_ref_jnp(trellis: Trellis, llr_i8: jnp.ndarray):
    """Batched forward with the Pallas-kernel contract.

    llr_i8: [B, T, R] int8  ->  (sp [B, T, n_sp_words] uint32,
                                 pm [B, N] float32)
    """
    import jax
    B, T, R = llr_i8.shape
    N = trellis.n_states
    half = N // 2
    cw_signs = jnp.asarray(trellis.cw_signs)              # [R, 2^R]
    top0 = jnp.asarray(trellis.cw_top0)
    top1 = jnp.asarray(trellis.cw_top1)
    bot0 = jnp.asarray(trellis.cw_bot0)
    bot1 = jnp.asarray(trellis.cw_bot1)
    word_states = jnp.asarray(trellis.word_states)        # [W, 32]
    valid = (word_states >= 0)
    gather_idx = jnp.where(valid, word_states, 0)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    llr_f = llr_i8.astype(jnp.float32)

    def stage(pm, llr_s):
        bm = llr_s @ cw_signs                              # [B, 2^R]
        pmr = pm.reshape(B, half, 2)
        pe, po = pmr[:, :, 0], pmr[:, :, 1]
        ta = pe + bm[:, top0]
        tb = po + bm[:, top1]
        ba = pe + bm[:, bot0]
        bb = po + bm[:, bot1]
        sel_top = (tb < ta)
        sel_bot = (bb < ba)
        new_pm = jnp.concatenate(
            [jnp.where(sel_top, tb, ta), jnp.where(sel_bot, bb, ba)], axis=1
        )
        new_pm = new_pm - new_pm.min(axis=1, keepdims=True)
        sel = jnp.concatenate([sel_top, sel_bot], axis=1)  # [B, N] bool
        g = sel[:, gather_idx].astype(jnp.uint32) & valid.astype(jnp.uint32)
        words = (g << shifts).sum(axis=2, dtype=jnp.uint32)  # [B, W]
        return new_pm, words

    pm0 = jnp.zeros((B, N), jnp.float32)
    pm, sp_t = jax.lax.scan(stage, pm0, jnp.swapaxes(llr_f, 0, 1))
    return jnp.swapaxes(sp_t, 0, 1), pm


def traceback_ref_jnp(
    trellis: Trellis, sp: jnp.ndarray, D: int, L: int
) -> jnp.ndarray:
    """Batched traceback with the kernel contract.

    sp: [B, T, W] uint32  ->  packed bits [B, D//32] uint32
    (D must be a multiple of 32).
    """
    import jax
    B, T, W = sp.shape
    assert T == D + 2 * L and D % 32 == 0
    v = trellis.v
    tb_word = jnp.asarray(trellis.sp_word)
    tb_bit = jnp.asarray(trellis.sp_bit.astype(np.uint32))
    mask = (1 << (v - 1)) - 1

    def step(state, sp_s):
        w = tb_word[state]                                 # [B]
        b = tb_bit[state]
        word = jnp.take_along_axis(sp_s, w[:, None], axis=1)[:, 0]
        bit = ((word >> b) & 1).astype(jnp.int32)
        out = (state >> (v - 1)) & 1
        nxt = 2 * (state & mask) + bit
        return nxt, out

    sp_rev = jnp.swapaxes(sp, 0, 1)[::-1]                  # [T, B, W], s=T-1 first
    state0 = jnp.zeros((B,), jnp.int32)
    # merge phase: stages T-1 .. D+L  (first L reversed steps)
    state, _ = jax.lax.scan(step, state0, sp_rev[:L])
    # decode phase: stages D+L-1 .. L (next D steps), bits emitted reversed
    _, bits_rev = jax.lax.scan(step, state, sp_rev[L:L + D])
    bits = bits_rev[::-1]                                  # [D, B]
    bits = jnp.swapaxes(bits, 0, 1).astype(jnp.uint32)     # [B, D]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (bits.reshape(B, D // 32, 32) << shifts).sum(
        axis=2, dtype=jnp.uint32
    )


def unpack_bits_np(packed: np.ndarray, D: int) -> np.ndarray:
    """[B, D//32] uint32 -> [B, D] int8 (bit d at word d//32, bit d%32)."""
    B = packed.shape[0]
    out = np.zeros((B, D), dtype=np.int8)
    for d in range(D):
        out[:, d] = (packed[:, d // 32] >> (d % 32)) & 1
    return out
