"""K1 — forward ACS Pallas kernel (paper Algorithm 1, Kernel 1).

TPU adaptation of the paper's group-based forward kernel (DESIGN.md §2):

  * The CUDA grid (N_bl blocks x 32N_c threads, one warp per group) maps
    to a Pallas grid over batch tiles of ``TILE_B`` parallel blocks; the
    per-group threads become a full vector ACS over all N states per
    lane.
  * The paper's insight — butterflies in a group share four branch
    metrics, so one stage needs only 2^{R+2} BM computations — becomes:
    compute the 2^R-entry BM table once per stage per lane
    (``llr_s @ cw_signs``) and *gather* per butterfly, instead of the
    state-based scheme's 2^K per-transition correlations.
  * Shared-memory PM[N][32] becomes the scan carry (VMEM-resident under
    a real Mosaic lowering); survivor bits are packed into
    ``n_sp_words`` u32 words per stage exactly as Fig. 3 (2 bits per
    butterfly, grouped by alpha-class).

Trellis tables are compile-time data but Pallas requires them as kernel
operands, so they ride along as small ANY-memory inputs with a
whole-array BlockSpec.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode emits plain HLO (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..trellis import Trellis


def _acs_stage(pm, llr_s, cw_signs, labels, pack, tile_b, half, normalize):
    """One ACS stage shared by the kernel body; returns (new_pm, sp_words).

    ``pack`` is either ("gather", gather_idx, valid_u32) — the Fig.-3
    word assembly via per-word state gathers — or ("matmul", w_lo, w_hi)
    — the §Perf-optimized form: two [B,N]x[N,W] f32 contractions with
    power-of-two weights split into 16-bit halves (every partial sum
    stays < 2^24, so f32 is exact).  The matmul form is both faster on
    CPU-XLA and the MXU-friendly shape on a real TPU.
    """
    # Branch-metric table: ONE [B,R]x[R,2^R] product per stage — the
    # group-based scheme (2^R metrics), not 2^K per-transition work.
    bm = llr_s @ cw_signs                                 # [B, 2^R]
    pmr = pm.reshape(tile_b, half, 2)
    pe, po = pmr[:, :, 0], pmr[:, :, 1]
    ta = pe + bm[:, labels[0]]      # alpha: 2j   --0--> j
    tb = po + bm[:, labels[1]]      # gamma: 2j+1 --0--> j
    ba = pe + bm[:, labels[2]]      # beta:  2j   --1--> j+N/2
    bb = po + bm[:, labels[3]]      # theta: 2j+1 --1--> j+N/2
    sel_top = tb < ta
    sel_bot = bb < ba
    new_pm = jnp.concatenate(
        [jnp.where(sel_top, tb, ta), jnp.where(sel_bot, bb, ba)], axis=1
    )
    if normalize:
        # Rescale so PMs stay bounded over arbitrarily long blocks.
        new_pm = new_pm - new_pm.min(axis=1, keepdims=True)
    # Survivor bits, packed per Fig. 3: word w <- bits of group w.
    sel = jnp.concatenate([sel_top, sel_bot], axis=1)     # [B, N]
    if pack[0] == "gather":
        _, gather_idx, valid_u32 = pack
        g = sel[:, gather_idx].astype(jnp.uint32) & valid_u32  # [B, W, 32]
        shifts = jax.lax.broadcasted_iota(jnp.uint32, g.shape, 2)
        words = (g << shifts).sum(axis=2, dtype=jnp.uint32)   # [B, W]
    else:
        _, w_lo, w_hi = pack
        sel_f = sel.astype(jnp.float32)
        lo = (sel_f @ w_lo).astype(jnp.uint32)            # bits 0..15
        hi = (sel_f @ w_hi).astype(jnp.uint32)            # bits 16..31
        words = lo | (hi << jnp.uint32(16))
    return new_pm, words


def _forward_kernel_body(
    llr_ref, cw_signs_ref, labels_ref, p0_ref, p1_ref,
    sp_ref, pm_ref, *, n_states: int, pack_mode: str, norm_mode: str,
):
    """llr [TILE_B, T, R] i8 -> sp [TILE_B, T, W] u32, pm [TILE_B, N] f32.

    ``norm_mode``:
      * "stage" — subtract the per-stage minimum (textbook; extra [B,N]
        reduce every stage).
      * "final" — §Perf optimization: integer-valued f32 PMs grow by at
        most 2·R·127 per stage, so for T·2·R·127 < 2^24 (T < 33k for
        R = 2) the accumulation is exact and a SINGLE subtraction at the
        end produces *identical* PMs (per-stage min subtraction only
        shifts all metrics by a shared constant) and identical survivor
        decisions.
    """
    tile_b, T, R = llr_ref.shape
    half = n_states // 2

    cw_signs = cw_signs_ref[...]
    labels = labels_ref[...]          # [4, half] int32 (top0,top1,bot0,bot1)
    if pack_mode == "gather":
        pack = ("gather", p0_ref[...], p1_ref[...])
    else:
        pack = ("matmul", p0_ref[...], p1_ref[...])

    llr = llr_ref[...].astype(jnp.float32)                   # [B, T, R]
    if norm_mode == "final":
        assert T * 2 * R * 127 < (1 << 24), "final-norm overflow bound"

    def stage(pm, llr_s):
        return _acs_stage(
            pm, llr_s, cw_signs, labels, pack, tile_b, half,
            normalize=(norm_mode == "stage"),
        )

    pm0 = jnp.zeros((tile_b, n_states), jnp.float32)
    pm, sp_t = jax.lax.scan(stage, pm0, jnp.swapaxes(llr, 0, 1))
    if norm_mode == "final":
        pm = pm - pm.min(axis=1, keepdims=True)
    sp_ref[...] = jnp.swapaxes(sp_t, 0, 1)
    pm_ref[...] = pm


def forward_tables(trellis: Trellis, pack_mode: str = "gather"):
    """Trellis tables in the operand form the kernels consume.

    Returns (cw_signs, labels, p0, p1) where (p0, p1) depend on the
    packing mode: gather -> (gather_idx, valid mask); matmul -> the
    16-bit-split power-of-two weight matrices (see `_acs_stage`).
    """
    labels = np.stack(
        [trellis.cw_top0, trellis.cw_top1, trellis.cw_bot0, trellis.cw_bot1]
    ).astype(np.int32)
    if pack_mode == "gather":
        p0 = np.where(
            trellis.word_states >= 0, trellis.word_states, 0
        ).astype(np.int32)
        p1 = (trellis.word_states >= 0).astype(np.uint32)
    elif pack_mode == "matmul":
        n = trellis.n_states
        w = trellis.n_sp_words
        p0 = np.zeros((n, w), dtype=np.float32)  # bits 0..15
        p1 = np.zeros((n, w), dtype=np.float32)  # bits 16..31
        for s in range(n):
            word, bit = int(trellis.sp_word[s]), int(trellis.sp_bit[s])
            if bit < 16:
                p0[s, word] = float(1 << bit)
            else:
                p1[s, word] = float(1 << (bit - 16))
    else:
        raise ValueError(f"unknown pack_mode {pack_mode!r}")
    return trellis.cw_signs, labels, p0, p1


def _table_spec(shape):
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def forward_pallas(
    trellis: Trellis,
    llr_i8: jnp.ndarray,
    *,
    tile_b: int = 8,
    pack_mode: str = "gather",
    norm_mode: str = "final",
):
    """Batched forward ACS: llr [B, T, R] int8 ->
    (sp [B, T, n_sp_words] uint32, pm [B, N] float32).

    ``B`` must be a multiple of ``tile_b``; the Pallas grid runs one
    program per tile of ``tile_b`` parallel blocks.  The defaults are
    the §Perf-measured best on CPU-XLA (gather packing + deferred
    normalization, ~15% over the textbook per-stage form); on a real
    TPU prefer ``pack_mode="matmul"`` — the packing becomes two MXU
    contractions instead of VPU gathers.  All four combinations produce
    bit-identical outputs (asserted by tests and EXPERIMENTS.md §Perf).
    """
    B, T, R = llr_i8.shape
    assert R == trellis.R
    assert B % tile_b == 0, (B, tile_b)
    W = trellis.n_sp_words
    N = trellis.n_states
    cw_signs, labels, p0, p1 = forward_tables(trellis, pack_mode)
    kernel = functools.partial(
        _forward_kernel_body, n_states=N, pack_mode=pack_mode,
        norm_mode=norm_mode,
    )
    sp, pm = pl.pallas_call(
        kernel,
        grid=(B // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, T, R), lambda i: (i, 0, 0)),
            _table_spec(cw_signs.shape),
            _table_spec(labels.shape),
            _table_spec(p0.shape),
            _table_spec(p1.shape),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, T, W), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, N), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.uint32),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
        ],
        interpret=True,
    )(llr_i8, cw_signs, labels, p0, p1)
    return sp, pm


# ---------------------------------------------------------------------------
# State-based baseline (the "original decoder" of Table III): computes a
# per-transition correlation for every state instead of the shared 2^R
# table — 2^K * R multiply-adds per stage vs 2^R * R.
# ---------------------------------------------------------------------------

def statebased_tables(trellis: Trellis):
    """Per-transition sign matrices [4, R, N/2] for the baseline."""
    R = trellis.R
    half = trellis.n_states // 2

    def signs(label_row):
        m = np.zeros((R, half), dtype=np.float32)
        for j, c in enumerate(label_row):
            for r in range(R):
                bit = (int(c) >> (R - 1 - r)) & 1
                m[r, j] = 1.0 if bit else -1.0
        return m

    mats = np.stack([
        signs(trellis.cw_top0), signs(trellis.cw_top1),
        signs(trellis.cw_bot0), signs(trellis.cw_bot1),
    ])
    gather_idx = np.where(
        trellis.word_states >= 0, trellis.word_states, 0
    ).astype(np.int32)
    valid = (trellis.word_states >= 0).astype(np.uint32)
    return mats, gather_idx, valid


def _forward_statebased_body(
    llr_ref, mats_ref, gather_ref, valid_ref, sp_ref, pm_ref, *, n_states: int
):
    tile_b, T, R = llr_ref.shape
    half = n_states // 2
    mats = mats_ref[...]              # [4, R, half]
    gather_idx = gather_ref[...]
    valid_u32 = valid_ref[...]
    llr = llr_ref[...].astype(jnp.float32)

    def stage(pm, llr_s):
        pmr = pm.reshape(tile_b, half, 2)
        pe, po = pmr[:, :, 0], pmr[:, :, 1]
        # Four full [B,R]x[R,half] products — 2^K-scale BM work.
        ta = pe + llr_s @ mats[0]
        tb = po + llr_s @ mats[1]
        ba = pe + llr_s @ mats[2]
        bb = po + llr_s @ mats[3]
        sel_top = tb < ta
        sel_bot = bb < ba
        new_pm = jnp.concatenate(
            [jnp.where(sel_top, tb, ta), jnp.where(sel_bot, bb, ba)], axis=1
        )
        new_pm = new_pm - new_pm.min(axis=1, keepdims=True)
        sel = jnp.concatenate([sel_top, sel_bot], axis=1)
        g = sel[:, gather_idx].astype(jnp.uint32) & valid_u32
        shifts = jax.lax.broadcasted_iota(jnp.uint32, g.shape, 2)
        words = (g << shifts).sum(axis=2, dtype=jnp.uint32)
        return new_pm, words

    pm0 = jnp.zeros((tile_b, n_states), jnp.float32)
    pm, sp_t = jax.lax.scan(stage, pm0, jnp.swapaxes(llr, 0, 1))
    sp_ref[...] = jnp.swapaxes(sp_t, 0, 1)
    pm_ref[...] = pm


def forward_statebased_pallas(
    trellis: Trellis, llr: jnp.ndarray, *, tile_b: int = 8
):
    """State-based-parallelism forward (baseline), f32 input."""
    B, T, R = llr.shape
    assert B % tile_b == 0
    W = trellis.n_sp_words
    N = trellis.n_states
    mats, gather_idx, valid = statebased_tables(trellis)
    kernel = functools.partial(_forward_statebased_body, n_states=N)
    sp, pm = pl.pallas_call(
        kernel,
        grid=(B // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, T, R), lambda i: (i, 0, 0)),
            _table_spec(mats.shape),
            _table_spec(gather_idx.shape),
            _table_spec(valid.shape),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, T, W), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, N), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.uint32),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
        ],
        interpret=True,
    )(llr, mats, gather_idx, valid)
    return sp, pm
