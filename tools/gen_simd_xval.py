#!/usr/bin/env python3
"""Regenerate BENCH_simd_xval.json — the committed bit-identity
cross-validation record of the lane-interleaved SIMD kernel algorithm
(python port of rust/src/{par,simd}.rs and the backend schedules of
rust/src/simd/backend.rs) against the golden CpuPbvdDecoder model, at
every metric width and both stage-kernel schedules.

Schema 3: every row carries `metric_width`, `lanes` AND `backend` —
`"full-width"` for the 256-bit AVX2/scalar schedule (`simd_forward`)
and `"half-vector"` for the 128-bit NEON/portable lane-chunk schedule
(`simd_forward_halves`) — so new width modes and new backends both add
rows instead of overwriting the existing record (schema 1 rows had no
width; schema 2 rows no backend).

Schema 4 adds the depth-windowed survivor-ring checks: the kernels now
store decision rows in a C = D + L ring (`s % C`) instead of the full
T = D + 2L buffer, and per-code `survivor ring == full buffer` rows
prove the windowed traceback bit-exact against both the full-length
port and the golden model (including depth >= block geometries, where
the ring wraps more than once per forward pass).

Usage (from the repo root):
    PYTHONPATH=python python3 tools/gen_simd_xval.py [out.json]
"""
import json
import random
import sys

sys.path.insert(0, "python")
sys.path.insert(0, "python/tests")

from compile.trellis import build_trellis  # noqa: E402
from test_simd_lockstep_port import (  # noqa: E402
    LANES_BY_WIDTH,
    fill_bm_lanes,
    golden_forward,
    golden_forward_ring,
    golden_traceback,
    golden_traceback_ring,
    gray_walk,
    ring_stages,
    simd_forward,
    simd_forward_ring,
    simd_forward_halves,
    simd_traceback,
    simd_traceback_ring,
    spread_bound,
)

CODES = ["ccsds_k7", "k5", "k9", "r3_k7", "k3"]
WIDTHS = [32, 16]
# schedule name -> forward implementation (the python models of the
# Rust backend seam: full-width = scalar/AVX2, half-vector = portable/NEON)
BACKENDS = {"full-width": simd_forward, "half-vector": simd_forward_halves}


def check_gray_fill(width, trials=200):
    rnd = random.Random(0x6FA1)
    lanes = LANES_BY_WIDTH[width]
    rs = [1, 2, 3, 4]
    for r in rs:
        for _ in range(trials // len(rs)):
            sv = [[rnd.randint(-128, 127) for _ in range(lanes)] for _ in range(r)]
            bm = fill_bm_lanes(sv, r, width)
            off = r * 128
            for c in range(1 << r):
                for lane in range(lanes):
                    acc = sum(
                        sv[ri][lane] * (2 * ((c >> (r - 1 - ri)) & 1) - 1)
                        for ri in range(r)
                    )
                    assert bm[c][lane] == off + acc
    return {
        "name": "gray_fill_bm == direct_fill_bm",
        "metric_width": width,
        "lanes": lanes,
        "backend": "full-width",
        "r": rs,
        "trials": trials,
        "pass": True,
    }


def check_lockstep(code, width, backend, trials=3):
    t = build_trellis(code)
    forward = BACKENDS[backend]
    lanes = LANES_BY_WIDTH[width]
    block, depth = 24, 6 * t.K
    tt = block + 2 * depth
    rnd = random.Random(0xB1F ^ width)
    starts = [0, 1, t.n_states - 1]
    extreme = [
        [-128] * (tt * t.R),
        [(-128 if i % 2 == 0 else 127) for i in range(tt * t.R)],
    ]
    any_saturated = False
    for trial in range(trials):
        lane_llrs = [
            [rnd.randint(-128, 127) for _ in range(tt * t.R)] for _ in range(lanes)
        ]
        if trial == 0:  # plant the adversarial extremes in lanes 0/1
            lane_llrs[0] = list(extreme[0])
            lane_llrs[1] = list(extreme[1])
        dw, pm, saturated = forward(t, lane_llrs, block, depth, width)
        any_saturated |= saturated
        if backend == "half-vector":
            # the two schedules must agree word-for-word before either
            # is compared to golden
            dw_full, pm_full, _ = simd_forward(t, lane_llrs, block, depth, width)
            assert dw == dw_full and pm == pm_full, \
                f"{code} u{width}: half-vector schedule diverged from full-width"
        for lane in range(lanes):
            sel_rows, gpm = golden_forward(t, lane_llrs[lane], block, depth)
            assert [pm[st][lane] for st in range(t.n_states)] == gpm
            for s0 in starts:
                assert simd_traceback(t, dw, lane, block, depth, s0) == golden_traceback(
                    t, sel_rows, block, depth, s0
                )
    assert not any_saturated, f"{code} u{width}: saturation fired inside the bound"
    return {
        "name": f"lockstep kernel == golden ({code})",
        "metric_width": width,
        "lanes": lanes,
        "backend": backend,
        "n_states": t.n_states,
        "trials": trials,
        "lanes_checked": lanes,
        "start_states": starts,
        "includes_i8_extremes": True,
        "saturation_fired": False,
        "spread_bound": spread_bound(t.R, t.K),
        "decisions_bit_identical": True,
    }


def check_splice(width):
    t = build_trellis("ccsds_k7")
    lanes = LANES_BY_WIDTH[width]
    block, depth = 24, 18
    per_pb = (block + 2 * depth) * t.R
    rnd = random.Random(3 ^ width)
    batches = [1, lanes - 1, lanes, 3 * lanes + 2]
    for batch in batches:
        llr = [rnd.randint(-128, 127) for _ in range(batch * per_pb)]
        want = []
        for b in range(batch):
            sel, _ = golden_forward(t, llr[b * per_pb:(b + 1) * per_pb], block, depth)
            want.extend(golden_traceback(t, sel, block, depth, 0))
        got = []
        full = batch // lanes
        for g in range(full):  # full lane-groups through the lockstep kernel
            lane_llrs = [
                llr[(g * lanes + l) * per_pb:(g * lanes + l + 1) * per_pb]
                for l in range(lanes)
            ]
            dw, _, _ = simd_forward(t, lane_llrs, block, depth, width)
            for lane in range(lanes):
                got.extend(simd_traceback(t, dw, lane, block, depth, 0))
        off = full * lanes
        if width == 16 and batch - off >= LANES_BY_WIDTH[32]:
            # u16 tails of 8..16 PBs peel one u32 lane-group (dispatch
            # plan in rust/src/simd.rs)
            l32 = LANES_BY_WIDTH[32]
            lane_llrs = [llr[(off + l) * per_pb:(off + l + 1) * per_pb] for l in range(l32)]
            dw, _, _ = simd_forward(t, lane_llrs, block, depth, 32)
            for lane in range(l32):
                got.extend(simd_traceback(t, dw, lane, block, depth, 0))
            off += l32
        for p in range(off, batch):  # scalar ragged tail
            sel, _ = golden_forward(t, llr[p * per_pb:(p + 1) * per_pb], block, depth)
            got.extend(golden_traceback(t, sel, block, depth, 0))
        assert got == want, f"u{width} batch={batch}"
    return {
        "name": "lane-group partition + ragged tail + splice (ccsds_k7)",
        "metric_width": width,
        "lanes": lanes,
        "backend": "full-width",
        "batches": batches,
        "u16_tail_peels_u32_group": width == 16,
        "pass": True,
    }


def check_ring(code, width):
    """Depth-windowed survivor ring == full buffer == golden, per code,
    on a depth < block AND a depth >= block geometry (the ring wraps
    more than once per forward in the latter)."""
    t = build_trellis(code)
    lanes = LANES_BY_WIDTH[width]
    geometries = [(24, 2 * t.K), (8, 6 * t.K)]  # depth < block / depth >= block
    rnd = random.Random(0x21C6 ^ width)
    rows = []
    for block, depth in geometries:
        tt = block + 2 * depth
        c = ring_stages(block, depth)
        assert c == block + depth and c < tt
        lane_llrs = [
            [rnd.randint(-128, 127) for _ in range(tt * t.R)] for _ in range(lanes)
        ]
        dw, pm, _ = simd_forward(t, lane_llrs, block, depth, width)
        dw_ring, pm_ring, _ = simd_forward_ring(t, lane_llrs, block, depth, width)
        assert pm_ring == pm and len(dw_ring) == c
        for s in range(depth, tt):  # every retained stage reads back identically
            assert dw_ring[s % c] == dw[s], f"{code} u{width} stage {s}"
        for lane in range(lanes):
            sel_ring, gpm = golden_forward_ring(t, lane_llrs[lane], block, depth)
            assert [pm_ring[st][lane] for st in range(t.n_states)] == gpm
            for s0 in (0, 1, t.n_states - 1):
                want = golden_traceback_ring(t, sel_ring, block, depth, s0)
                assert simd_traceback_ring(t, dw_ring, lane, block, depth, s0) == want
                assert simd_traceback(t, dw, lane, block, depth, s0) == want
        rows.append({
            "block": block,
            "depth": depth,
            "total_stages": tt,
            "ring_stages": c,
            "survivor_ratio": round(c / tt, 4),
            "wraps_more_than_once": depth >= block,
        })
    return {
        "name": f"survivor ring == full buffer == golden ({code})",
        "metric_width": width,
        "lanes": lanes,
        "backend": "full-width",
        "geometries": rows,
        "start_states": [0, 1, t.n_states - 1],
        "decisions_bit_identical": True,
    }


def main(out_path):
    checks = []
    for width in WIDTHS:
        checks.append(check_gray_fill(width))
        for backend in BACKENDS:
            for code in CODES:
                checks.append(check_lockstep(code, width, backend))
        for code in CODES:
            checks.append(check_ring(code, width))
        checks.append(check_splice(width))
    report = {
        "bench": "simd_cross_validation",
        "source": (
            "python port of rust/src/{par,simd}.rs + the backend schedules of "
            "rust/src/simd/backend.rs vs golden CpuPbvdDecoder "
            "(no rust toolchain in the build container); regenerate with "
            "tools/gen_simd_xval.py"
        ),
        "schema": 4,
        "metric_widths": WIDTHS,
        "lanes_by_width": {str(w): LANES_BY_WIDTH[w] for w in WIDTHS},
        "backends": sorted(BACKENDS),
        "survivor_ring": {
            "capacity": "block + depth",
            "slot": "stage % capacity",
            "note": (
                "decision rows live in a D+L ring instead of the full "
                "D+2L buffer; traceback only reads stages depth..T-1, "
                "which map bijectively onto the ring rows"
            ),
        },
        "checks": checks,
        "all_bit_identical": True,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {out_path}: {len(checks)} checks, all bit-identical")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_simd_xval.json")
