#!/usr/bin/env python3
"""Advisory check: flag a lane-interleaved SIMD kernel regression below
the scalar baseline — or the narrow-metric u16 kernel regressing below
the u32 kernel — in the bench-smoke JSON reports.

Usage: check_simd_bench.py [--audit-overhead[=PCT]] BENCH_cpu_kernels.json [BENCH_table3.json ...]

Reads any of:
  - BENCH_cpu_kernels.json  "simd" rows:
        {code, backend?, scalar_mbps, simd_mbps, simd16_mbps?}
    and "backends" rows (per-ACS-backend kernel ladder, reported only):
        {code, backend, metric_width, mbps}
  - BENCH_table3.json       scalars:
        scalar_w1_mbps / simd_w1_mbps / simd16_w1_mbps?
        autotune_pick_bits? / backend? (logged, never a regression by
        themselves)

The `backend` fields record which ACS stage-kernel implementation
(scalar / portable / avx2 / neon) produced the numbers, so a perf
delta across runs can be attributed to a backend change rather than a
code change.

With --audit-overhead (optionally --audit-overhead=PCT, default 5),
"audit" rows — {engine?, off_mbps, on_mbps, sample_ppm?} pairs
measured with the shadow auditor disabled vs at the given sampling
rate — are checked too: an overhead above PCT percent is flagged.
Without the flag, audit rows are printed as info only.

Exit status 1 on any regression (the SIMD path slower than scalar, or
u16 slower than u32); CI runs this with continue-on-error so it warns
without gating merges.  Missing files/sections/keys are skipped (e.g. a
bench that did not run, or a pre-u16 report).
"""
import json
import sys


def compare(label, base_name, base, cand_name, cand, regressions):
    """One advisory comparison; returns True if it was checkable."""
    if base is None or cand is None:
        return False
    tag = f"{label}: {base_name} {base:.2f} Mbps vs {cand_name} {cand:.2f} Mbps"
    if cand < base:
        regressions.append(f"SIMD width below baseline — {tag}")
    else:
        print(f"ok   {tag} (x{cand / base:.2f})")
    return True


def check_audit(path, rep, limit_pct, regressions):
    """Advisory shadow-audit overhead check; returns comparisons made."""
    checked = 0
    for row in rep.get("audit", []):
        off = row.get("off_mbps")
        on = row.get("on_mbps")
        if off is None or on is None or off <= 0:
            continue
        overhead = (off - on) / off * 100.0
        label = "{}: audit {} ppm={}".format(
            path, row.get("engine", "?"), row.get("sample_ppm", "?")
        )
        line = f"{label} {off:.2f} -> {on:.2f} Mbps ({overhead:+.1f}%)"
        if limit_pct is None:
            print(f"info {line}")
            continue
        checked += 1
        if overhead > limit_pct:
            regressions.append(f"{line} exceeds the {limit_pct:.1f}% budget")
        else:
            print(f"ok   {line}")
    return checked


def main(argv):
    audit_limit = None
    paths = []
    for a in argv:
        if a == "--audit-overhead":
            audit_limit = 5.0
        elif a.startswith("--audit-overhead="):
            audit_limit = float(a.split("=", 1)[1])
        else:
            paths.append(a)
    if not paths:
        paths = ["BENCH_cpu_kernels.json", "BENCH_table3.json"]
    regressions = []
    checked = 0
    for path in paths:
        try:
            with open(path) as f:
                rep = json.load(f)
        except OSError:
            print(f"skip {path}: not found")
            continue
        for row in rep.get("simd", []):
            code = row.get("code", "?")
            backend = row.get("backend", "?")
            scalar = row.get("scalar_mbps")
            simd = row.get("simd_mbps")
            simd16 = row.get("simd16_mbps")
            label = f"{path}: {code} [{backend}]"
            checked += compare(label, "scalar", scalar, "simd-u32", simd, regressions)
            checked += compare(label, "simd-u32", simd, "simd-u16", simd16, regressions)
        for row in rep.get("backends", []):
            mbps = row.get("mbps")
            if mbps is None:
                continue
            print(
                "info {}: {} u{} backend={} {:.2f} Mbps".format(
                    path,
                    row.get("code", "?"),
                    row.get("metric_width", "?"),
                    row.get("backend", "?"),
                    mbps,
                )
            )
        checked += compare(
            f"{path}: 1-worker T/P",
            "scalar",
            rep.get("scalar_w1_mbps"),
            "simd-u32",
            rep.get("simd_w1_mbps"),
            regressions,
        )
        checked += compare(
            f"{path}: 1-worker T/P",
            "simd-u32",
            rep.get("simd_w1_mbps"),
            "simd-u16",
            rep.get("simd16_w1_mbps"),
            regressions,
        )
        pick = rep.get("autotune_pick_bits")
        if pick is not None:
            print(f"info {path}: lane-width autotune picked u{pick}")
        backend = rep.get("backend")
        if backend is not None:
            print(f"info {path}: auto-resolved ACS backend = {backend}")
        checked += check_audit(path, rep, audit_limit, regressions)
    if not checked:
        print("no scalar-vs-simd rows found; nothing to check")
        return 0
    for r in regressions:
        print(f"REGRESSION (advisory): {r}")
    print(f"{checked} comparison(s), {len(regressions)} regression(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["BENCH_cpu_kernels.json", "BENCH_table3.json"]))
