#!/usr/bin/env python3
"""Advisory check: flag a lane-interleaved SIMD kernel regression below
the scalar baseline in the bench-smoke JSON reports.

Usage: check_simd_bench.py BENCH_cpu_kernels.json [BENCH_table3.json ...]

Reads any of:
  - BENCH_cpu_kernels.json  "simd" rows: {code, scalar_mbps, simd_mbps}
  - BENCH_table3.json       scalars: scalar_w1_mbps / simd_w1_mbps

Exit status 1 on any regression (the SIMD path slower than scalar); CI
runs this with continue-on-error so it warns without gating merges.
Missing files/sections are skipped (e.g. a bench that did not run).
"""
import json
import sys


def main(paths):
    regressions = []
    checked = 0
    for path in paths:
        try:
            with open(path) as f:
                rep = json.load(f)
        except OSError:
            print(f"skip {path}: not found")
            continue
        for row in rep.get("simd", []):
            checked += 1
            code = row.get("code", "?")
            scalar, simd = row.get("scalar_mbps"), row.get("simd_mbps")
            if scalar is None or simd is None:
                continue
            tag = f"{path}: {code} scalar {scalar:.2f} Mbps vs simd {simd:.2f} Mbps"
            if simd < scalar:
                regressions.append(tag)
            else:
                print(f"ok   {tag} (x{simd / scalar:.2f})")
        scalar, simd = rep.get("scalar_w1_mbps"), rep.get("simd_w1_mbps")
        if scalar is not None and simd is not None:
            checked += 1
            tag = f"{path}: 1-worker T/P scalar {scalar:.2f} Mbps vs simd {simd:.2f} Mbps"
            if simd < scalar:
                regressions.append(tag)
            else:
                print(f"ok   {tag} (x{simd / scalar:.2f})")
    if not checked:
        print("no scalar-vs-simd rows found; nothing to check")
        return 0
    for r in regressions:
        print(f"REGRESSION (advisory): SIMD below scalar baseline — {r}")
    print(f"{checked} comparison(s), {len(regressions)} regression(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["BENCH_cpu_kernels.json", "BENCH_table3.json"]))
