#!/usr/bin/env python3
"""Advisory check: flag a lane-interleaved SIMD kernel regression below
the scalar baseline — or the narrow-metric u16 kernel regressing below
the u32 kernel, or the survivor ring losing its depth window — in the
bench-smoke JSON reports.

Usage: check_simd_bench.py [--audit-overhead[=PCT]] [--plan] BENCH_cpu_kernels.json [BENCH_table3.json ...]

Reads any of:
  - BENCH_cpu_kernels.json  "simd" rows:
        {code, backend?, scalar_mbps, simd_mbps, simd16_mbps?,
         survivor_ring_bytes*?, survivor_full_bytes*?}
    "split_pool" rows (ACS/traceback pipelined pool vs fused pool):
        {engine, workers, fused_mbps, split_mbps, acs_busy_frac,
         tb_busy_frac, survivor_ring_bytes?, survivor_ring_stages?,
         survivor_total_stages?}
    and "backends" rows (per-ACS-backend kernel ladder, reported only):
        {code, backend, metric_width, mbps}
  - BENCH_table3.json       scalars:
        scalar_w1_mbps / simd_w1_mbps / simd16_w1_mbps?
        autotune_pick_bits? / backend? (logged, never a regression by
        themselves)
    and "cpu_par" rows, whose survivor_ring_stages /
    survivor_total_stages (pool engines only) are window-checked.

Survivor checks: any row carrying a survivor_ring_bytes /
survivor_full_bytes pair must keep ring < full, and any row carrying
survivor_ring_stages / survivor_total_stages must keep ring stages <
total stages — either inverting means the depth-windowed ring
regressed to (or past) the full-length survivor buffer.  A split_pool
row whose tb_busy_frac is 0 is flagged too: the traceback phase never
ran as its own pipelined stage.

The `backend` fields record which ACS stage-kernel implementation
(scalar / portable / avx2 / neon) produced the numbers, so a perf
delta across runs can be attributed to a backend change rather than a
code change.

With --plan, the adaptive-dispatch rung scalars — plan_auto_mbps
measured with `engine auto` dispatching from the ladder's recorded
performance history, plus plan_workers / plan_engine /
plan_history_rows / plan_history_path / plan_machine provenance — are
checked against the best static cpu_par rung at the same worker
count: the dispatcher reading a freshly measured history should never
land on a known-slower arm.  Without the flag, plan scalars are
printed as info only.

With --audit-overhead (optionally --audit-overhead=PCT, default 5),
"audit" rows — {engine?, off_mbps, on_mbps, sample_ppm?} pairs
measured with the shadow auditor disabled vs at the given sampling
rate — are checked too: an overhead above PCT percent is flagged.
Without the flag, audit rows are printed as info only.

Exit status 1 on any regression (the SIMD path slower than scalar, u16
slower than u32, or a survivor-window violation); CI runs this with
continue-on-error so it warns
without gating merges.  Missing files/sections/keys are skipped (e.g. a
bench that did not run, or a pre-u16 report).
"""
import json
import sys


def compare(label, base_name, base, cand_name, cand, regressions):
    """One advisory comparison; returns True if it was checkable."""
    if base is None or cand is None:
        return False
    tag = f"{label}: {base_name} {base:.2f} Mbps vs {cand_name} {cand:.2f} Mbps"
    if cand < base:
        regressions.append(f"SIMD width below baseline — {tag}")
    else:
        print(f"ok   {tag} (x{cand / base:.2f})")
    return True


def check_survivor_window(label, row, regressions):
    """Window invariants on any row carrying survivor fields; returns
    the number of checkable comparisons."""
    checked = 0
    for suffix in ("", "_u16", "_scalar"):
        ring = row.get(f"survivor_ring_bytes{suffix}")
        full = row.get(f"survivor_full_bytes{suffix}")
        if ring is None or full is None:
            continue
        checked += 1
        tag = f"{label}: survivor{suffix or '-u32'} ring {ring} B vs full {full} B"
        if ring >= full:
            regressions.append(f"survivor ring not depth-windowed — {tag}")
        else:
            print(f"ok   {tag} ({100.0 * ring / full:.0f}%)")
    rs = row.get("survivor_ring_stages")
    ts = row.get("survivor_total_stages")
    # rows from poolless engines report 0/0 — nothing to window-check
    if rs is not None and ts is not None and (rs, ts) != (0, 0):
        checked += 1
        tag = f"{label}: survivor ring {rs} of {ts} stages"
        if rs >= ts:
            regressions.append(f"survivor ring not depth-windowed — {tag}")
        else:
            print(f"ok   {tag}")
    return checked


def check_split_pool(path, rep, regressions):
    """ACS/traceback split-pool rows: window + phase-attribution
    advisories; returns comparisons made."""
    checked = 0
    for row in rep.get("split_pool", []):
        label = "{}: split {} w={}".format(
            path, row.get("engine", "?"), row.get("workers", "?")
        )
        fused = row.get("fused_mbps")
        split = row.get("split_mbps")
        if fused and split:
            print(f"info {label} fused {fused:.2f} -> split {split:.2f} Mbps "
                  f"(x{split / fused:.2f})")
        tb = row.get("tb_busy_frac")
        if tb is not None:
            checked += 1
            if tb <= 0.0:
                regressions.append(
                    f"{label}: traceback phase never attributed "
                    "(tb_busy_frac=0 — split pool ran fused?)"
                )
            else:
                print(f"ok   {label} acs/tb busy split "
                      f"{100.0 * row.get('acs_busy_frac', 0.0):.1f}%/{100.0 * tb:.1f}%")
        checked += check_survivor_window(label, row, regressions)
    return checked


def check_audit(path, rep, limit_pct, regressions):
    """Advisory shadow-audit overhead check; returns comparisons made."""
    checked = 0
    for row in rep.get("audit", []):
        off = row.get("off_mbps")
        on = row.get("on_mbps")
        if off is None or on is None or off <= 0:
            continue
        overhead = (off - on) / off * 100.0
        label = "{}: audit {} ppm={}".format(
            path, row.get("engine", "?"), row.get("sample_ppm", "?")
        )
        line = f"{label} {off:.2f} -> {on:.2f} Mbps ({overhead:+.1f}%)"
        if limit_pct is None:
            print(f"info {line}")
            continue
        checked += 1
        if overhead > limit_pct:
            regressions.append(f"{line} exceeds the {limit_pct:.1f}% budget")
        else:
            print(f"ok   {line}")
    return checked


def check_plan(path, rep, gate, regressions):
    """Adaptive-dispatch rung vs the best static rung at the same
    worker count; returns comparisons made."""
    plan = rep.get("plan_auto_mbps")
    if plan is None:
        return 0
    label = "{}: plan auto w={} -> {} [{} history rows, machine {}]".format(
        path,
        rep.get("plan_workers", "?"),
        rep.get("plan_engine", "?"),
        rep.get("plan_history_rows", "?"),
        rep.get("plan_machine", "?"),
    )
    hist = rep.get("plan_history_path")
    if hist is not None:
        print(f"info {path}: plan history at {hist}")
    workers = rep.get("plan_workers")
    static_best = None
    for row in rep.get("cpu_par", []):
        mbps = row.get("tp_mbps")
        if mbps is None or row.get("workers") != workers:
            continue
        if static_best is None or mbps > static_best:
            static_best = mbps
    if not gate or static_best is None:
        print(f"info {label} {plan:.2f} Mbps")
        return 0
    tag = f"{label} {plan:.2f} Mbps vs static best {static_best:.2f} Mbps"
    if plan < static_best * 0.9:  # 10% slack: separate measurement runs
        regressions.append(f"adaptive dispatch below static best — {tag}")
    else:
        print(f"ok   {tag} (x{plan / static_best:.2f})")
    return 1


def main(argv):
    audit_limit = None
    plan_gate = False
    paths = []
    for a in argv:
        if a == "--audit-overhead":
            audit_limit = 5.0
        elif a.startswith("--audit-overhead="):
            audit_limit = float(a.split("=", 1)[1])
        elif a == "--plan":
            plan_gate = True
        else:
            paths.append(a)
    if not paths:
        paths = ["BENCH_cpu_kernels.json", "BENCH_table3.json"]
    regressions = []
    checked = 0
    for path in paths:
        try:
            with open(path) as f:
                rep = json.load(f)
        except OSError:
            print(f"skip {path}: not found")
            continue
        for row in rep.get("simd", []):
            code = row.get("code", "?")
            backend = row.get("backend", "?")
            scalar = row.get("scalar_mbps")
            simd = row.get("simd_mbps")
            simd16 = row.get("simd16_mbps")
            label = f"{path}: {code} [{backend}]"
            checked += compare(label, "scalar", scalar, "simd-u32", simd, regressions)
            checked += compare(label, "simd-u32", simd, "simd-u16", simd16, regressions)
            checked += check_survivor_window(label, row, regressions)
        for row in rep.get("cpu_par", []):
            label = "{}: {} w={}".format(
                path, row.get("engine", "?"), row.get("workers", "?")
            )
            checked += check_survivor_window(label, row, regressions)
        checked += check_split_pool(path, rep, regressions)
        for row in rep.get("backends", []):
            mbps = row.get("mbps")
            if mbps is None:
                continue
            print(
                "info {}: {} u{} backend={} {:.2f} Mbps".format(
                    path,
                    row.get("code", "?"),
                    row.get("metric_width", "?"),
                    row.get("backend", "?"),
                    mbps,
                )
            )
        checked += compare(
            f"{path}: 1-worker T/P",
            "scalar",
            rep.get("scalar_w1_mbps"),
            "simd-u32",
            rep.get("simd_w1_mbps"),
            regressions,
        )
        checked += compare(
            f"{path}: 1-worker T/P",
            "simd-u32",
            rep.get("simd_w1_mbps"),
            "simd-u16",
            rep.get("simd16_w1_mbps"),
            regressions,
        )
        pick = rep.get("autotune_pick_bits")
        if pick is not None:
            print(f"info {path}: lane-width autotune picked u{pick}")
        backend = rep.get("backend")
        if backend is not None:
            print(f"info {path}: auto-resolved ACS backend = {backend}")
        checked += check_audit(path, rep, audit_limit, regressions)
        checked += check_plan(path, rep, plan_gate, regressions)
    if not checked:
        print("no scalar-vs-simd rows found; nothing to check")
        return 0
    for r in regressions:
        print(f"REGRESSION (advisory): {r}")
    print(f"{checked} comparison(s), {len(regressions)} regression(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["BENCH_cpu_kernels.json", "BENCH_table3.json"]))
